"""Kernel micro-benchmarks: ref-path timing (CPU) + VMEM tiling derived
numbers for the TPU target (the kernels themselves are TPU programs; on CPU
we report the oracle path and the kernel's analytic HBM-traffic saving).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.kernels import ref


def run(fast: bool = False):
    # logprob_gather: the GSI scoring op. Derived: HBM bytes naive vs fused.
    B, S, d, V = (4, 32, 256, 8192) if fast else (8, 64, 512, 32768)
    h = jax.random.normal(jax.random.PRNGKey(0), (B, S, d))
    w = jax.random.normal(jax.random.PRNGKey(1), (d, V)) * 0.02
    lab = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, V)
    fn = jax.jit(lambda a, b, c: ref.logprob_gather_ref(a, b, c, V))
    _, us = common.timed(fn, h, w, lab)
    naive = B * S * V * 4 * 2          # logits write+read (f32)
    fused = B * S * 4 * 3              # m/s/picked accumulators only
    common.emit("kernel/logprob_gather_ref", us,
                f"hbm_naive={naive / 1e6:.1f}MB;hbm_fused={fused / 1e3:.1f}KB;"
                f"saving={naive / max(fused, 1):.0f}x")

    # flash attention
    B, S, H, KV, hd = (1, 128, 4, 2, 64) if fast else (2, 256, 8, 2, 64)
    q = jax.random.normal(jax.random.PRNGKey(3), (B, S, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(4), (B, S, KV, hd))
    v = jax.random.normal(jax.random.PRNGKey(5), (B, S, KV, hd))
    fn = jax.jit(lambda a, b, c: ref.flash_attention_ref(a, b, c))
    _, us = common.timed(fn, q, k, v)
    scores = B * H * S * S * 4
    common.emit("kernel/flash_attention_ref", us,
                f"scores_hbm={scores / 1e6:.1f}MB;"
                f"vmem_tile=128x128;flops={4 * B * H * S * S * hd / 1e9:.2f}G")

    # paged attention, fp vs int8-quantized pages: same decode gather, the
    # quant path reads half the page bytes (int8 codes) plus a per-page
    # (KV,) f32 scale row that rides the block-table scalar-prefetch.
    # Derived: achieved KV bytes per decoded token at each storage format.
    B, H, KV, hd, ps, nblk = (2, 4, 2, 32, 16, 4) if fast \
        else (4, 8, 2, 64, 16, 8)
    P = B * nblk + 4
    ks_ = jax.random.split(jax.random.PRNGKey(7), 5)
    q = jax.random.normal(ks_[0], (B, 1, H, hd))
    kp = jax.random.normal(ks_[1], (P, ps, KV, hd))
    vp = jax.random.normal(ks_[2], (P, ps, KV, hd))
    pt = jnp.arange(B * nblk, dtype=jnp.int32).reshape(B, nblk)
    pos = jnp.full((B,), nblk * ps - 1, jnp.int32)
    fn = jax.jit(lambda *a: ref.paged_attention_ref(*a))
    _, us_fp = common.timed(fn, q, kp, vp, pt, pos)
    sc = jnp.max(jnp.abs(kp), axis=(1, 3)) / 127.0
    kp8 = jnp.clip(jnp.round(kp / sc[:, None, :, None]),
                   -127, 127).astype(jnp.int8)
    vp8 = jnp.clip(jnp.round(vp / sc[:, None, :, None]),
                   -127, 127).astype(jnp.int8)
    fnq = jax.jit(lambda *a: ref.paged_attention_quant_ref(*a))
    _, us_q = common.timed(fnq, q, kp8, vp8, sc, sc, pt, pos)
    ctx = int(pos[0]) + 1
    fp_bytes = 2 * ctx * KV * hd * 4            # k+v rows read, f32
    q_bytes = 2 * ctx * KV * hd * 1 \
        + 2 * nblk * KV * 4                     # int8 rows + page scales
    common.emit("kernel/paged_attention_ref", us_fp,
                f"kv_bytes_per_token={fp_bytes / 1e3:.1f}KB;ctx={ctx}")
    common.emit("kernel/paged_attention_quant_ref", us_q,
                f"kv_bytes_per_token={q_bytes / 1e3:.1f}KB;ctx={ctx};"
                f"bytes_saving={fp_bytes / q_bytes:.2f}x;"
                f"dequant_fused_in_kernel=true")

    # rwkv6 scan
    B, T, H, hd = (1, 64, 4, 32) if fast else (2, 128, 8, 64)
    ks = jax.random.split(jax.random.PRNGKey(6), 6)
    r, kk, vv = (jax.random.normal(ks[i], (B, T, H, hd)) for i in range(3))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, H, hd))) * 0.5 + 0.45
    u = jax.random.normal(ks[4], (H, hd)) * 0.3
    s0 = jnp.zeros((B, H, hd, hd))
    fn = jax.jit(lambda *a: ref.rwkv6_scan_ref(*a))
    _, us = common.timed(fn, r, kk, vv, w, u, s0)
    state_traffic_naive = B * H * hd * hd * 4 * 2 * T
    common.emit("kernel/rwkv6_scan_ref", us,
                f"state_hbm_per_chunkless={state_traffic_naive / 1e6:.1f}MB;"
                f"kernel_keeps_state_in_vmem=true;chunk=64")
