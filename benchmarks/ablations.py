"""Paper Figures 5/6/9-11 + Table 4: acceptance curves, beta/u ablations,
chi-square estimates — run on the exact toy environment (cheap, exact) and
the trained synthetic engine (for chi^2 from real log-ratios).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import ToyEnv, theory


def fig5_acceptance_vs_n(fast: bool = False):
    env = ToyEnv(m=12, seed=0)
    beta, u = 1.0, 0.5
    ns = [1, 4, 16] if fast else [1, 4, 16, 64, 256]
    for n in ns:
        trials = min(100_000, 1_600_000 // n)
        g = env.run_gsi(jax.random.PRNGKey(n), n=n, beta=beta, u=u,
                        trials=trials)
        r = env.run_rsd(jax.random.PRNGKey(n + 1), n=n, beta=beta,
                        threshold=0.7, trials=trials)
        common.emit(f"fig5_acceptance/n{n}", 0.0,
                    f"gsi={float(g.accept.mean()):.3f};"
                    f"rsd={float(r.accept.mean()):.3f}")


def fig6_beta_phase_transition(fast: bool = False):
    """Acceptance rate vs beta shows the sharp transition (paper Fig. 6)."""
    env = ToyEnv(m=12, seed=0)
    n, u = 8, 0.5
    accepts = []
    betas = [0.25, 0.5, 1.0, 2.0, 4.0, 8.0, 20.0]
    for b in betas:
        g = env.run_gsi(jax.random.PRNGKey(int(b * 10)), n=n, beta=b, u=u,
                        trials=60_000)
        accepts.append(float(g.accept.mean()))
        common.emit(f"fig6_beta/beta{b}", 0.0, f"accept={accepts[-1]:.3f}")
    # the log-ratio term ~ 1/beta: small beta -> tilted rewards dominated by
    # log ratio -> acceptance collapses; large beta -> raw rewards
    common.emit("fig6_beta/transition", 0.0,
                f"min={min(accepts):.3f};max={max(accepts):.3f};"
                f"spread={max(accepts) - min(accepts):.3f}")


def fig9_u_ablation(fast: bool = False):
    requests = 6 if fast else 12
    problems = common.sample_problems(requests, seed=5)
    for u in ([0.2, 0.6] if fast else [0.0, 0.2, 0.4, 0.6, 0.8]):
        res = common.eval_method("gsi", 2, problems, seed=6, u=u)
        common.emit(f"fig9_u/u{u}", 0.0,
                    f"acc={res['accuracy']:.3f};"
                    f"accept={res['accept_rate']:.3f}")


def table4_chi2(fast: bool = False):
    """chi^2(pi_B || pi_S) MC estimates from engine log-ratios (Table 4)."""
    requests = 6 if fast else 12
    problems = common.sample_problems(requests, seed=7)
    res = common.eval_method("gsi", 4, problems, seed=8)
    # raw per-step traces are capped at stats.trace_limit (512) arrays;
    # these runs take <= max_steps << 512 engine steps, so the sample is
    # complete — longer consumers should use stats.trace_mean/trace_var
    ratios = np.concatenate([r.ravel() for r in res["stats"].logp_ratio])
    chi2 = float(theory.chi2_mc_estimate(jnp.asarray(ratios),
                                         jnp.zeros_like(jnp.asarray(ratios))))
    common.emit("table4_chi2/engine", 0.0,
                f"mean={np.mean(np.exp(np.clip(ratios, -30, 30)) - 1):.3f};"
                f"chi2_est={chi2:.3f};n_samples={ratios.size}")
    # exact toy-env values for reference
    for seed in range(3):
        env = ToyEnv(m=12, seed=seed)
        common.emit(f"table4_chi2/toy_seed{seed}", 0.0,
                    f"chi2={float(env.chi2):.3f}")


def theorem1_table(fast: bool = False):
    """Theorem 1: measured KL vs bound across n (EXPERIMENTS §Paper-claims)."""
    env = ToyEnv(m=12, seed=0)
    beta = 1.0
    tilted = env.tilted(beta)
    chi2 = float(env.chi2)
    rmax = float(env.r.max())
    for n in ([1, 4, 16] if fast else [1, 4, 16, 64]):
        trials = min(120_000, 2_000_000 // n)
        tr = env.run_gsi(jax.random.PRNGKey(n), n=n, beta=beta, u=0.5,
                         trials=trials)
        emp = env.histogram(tr.outcomes_tilde)
        kl = float(theory.kl_mc_estimate(tilted, emp * trials))
        bound = float(theory.theorem1_kl_bound(n, chi2, beta, rmax))
        common.emit(f"theorem1/n{n}", 0.0,
                    f"kl={kl:.5f};bound={bound:.5f};holds={kl <= bound}")


def run(fast: bool = False):
    fig5_acceptance_vs_n(fast)
    fig6_beta_phase_transition(fast)
    fig9_u_ablation(fast)
    table4_chi2(fast)
    theorem1_table(fast)
