"""Shared benchmark infrastructure: trained triple cache, engine cache,
CSV emission (``name,us_per_call,derived``)."""
from __future__ import annotations

import functools
import time

import jax
import numpy as np

from repro.config import GSIConfig
from repro.data import SyntheticReasoningTask
from repro.launch.serve import evaluate, toy_triple, train_triple
from repro.serving import GSIServingEngine

FAST = False          # set by run.py --fast
SMOKE = False         # set by run.py --smoke (CI: tiniest budgets)
_ROWS = []


def emit(name: str, us_per_call: float, derived: str) -> None:
    row = f"{name},{us_per_call:.1f},{derived}"
    _ROWS.append(row)
    print(row, flush=True)


def all_rows():
    return list(_ROWS)


def timed(fn, *args, repeats: int = 3, **kw):
    fn(*args, **kw)  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return out, (time.perf_counter() - t0) / repeats * 1e6


@functools.lru_cache(maxsize=1)
def get_task():
    return SyntheticReasoningTask(seed=0, min_terms=2, max_terms=3,
                                  max_value=9)


@functools.lru_cache(maxsize=1)
def get_triple():
    """Train the draft/target/PRM triple once, shared by all benchmarks."""
    task = get_task()
    d, t, p = toy_triple()
    steps = (40, 90) if SMOKE else (100, 220) if FAST else (150, 320)
    print(f"# training triple (draft {steps[0]} / target {steps[1]} steps)",
          flush=True)
    ps, pb, pp = train_triple(task, d, t, p, steps_draft=steps[0],
                              steps_target=steps[1], batch=24, seq=48)
    return (d, t, p), (ps, pb, pp)


_ENGINES = {}


def get_engine(mode: str, n: int, *, beta=8.0, u=0.4, max_steps=5,
               rsd_threshold=0.7) -> GSIServingEngine:
    key = (mode, n, beta, u, rsd_threshold)
    if key not in _ENGINES:
        cfgs, params = get_triple()
        g = GSIConfig(n=n, beta=beta, threshold_u=u, max_step_tokens=8,
                      max_steps=max_steps, min_step_reward=0.0)
        _ENGINES[key] = GSIServingEngine(
            *cfgs, *params, g, mode=mode, rsd_threshold=rsd_threshold,
            max_seq=112)
    return _ENGINES[key]


def eval_method(mode: str, n: int, problems, seed=0, **kw):
    task = get_task()
    eng = get_engine(mode, n, **kw)
    return evaluate(eng, task, problems, jax.random.PRNGKey(seed))


def sample_problems(count: int, seed=1):
    task = get_task()
    rng_state = np.random.default_rng(seed)
    # re-seed the task generator deterministically for reproducible sets
    task.rng = np.random.default_rng(seed)
    return [task.sample_problem() for _ in range(count)]


def shared_prefix_prompts(count: int, pre_len: int = 33, seed=11,
                          max_terms: int = 4, groups: int = 1):
    """A shared-prefix workload: every request carries a ``pre_len``-token
    preamble (the "system prompt") followed by a distinct question.

    With ``page_size=16`` a 33-token preamble spans two *full* pages plus
    one token, so the radix prefix cache can share exactly 32 prefill
    tokens per request after the first admission.

    ``groups > 1`` splits the request set into that many *blocks*, each
    with its own distinct preamble — the multi-replica router workload.
    Blocks (rather than interleaving) matter: round-robin placement then
    provably spreads every preamble group across all replicas, while
    preamble-affinity keeps each group on one replica.
    """
    from repro.data import SyntheticReasoningTask
    from repro.data.synthetic import D0
    if not 1 <= groups <= 10:
        # the preamble pattern phase-shifts a 10-digit alphabet, so only
        # 10 mutually distinct preambles exist; beyond that groups would
        # silently alias and locality comparisons would be meaningless
        raise ValueError(f"groups must be in [1, 10], got {groups}")
    task = SyntheticReasoningTask(seed=seed, min_terms=2,
                                  max_terms=max_terms, max_value=9)
    out = []
    per = -(-count // groups)
    for g in range(groups):
        pre = np.asarray([D0 + ((3 * g + i) % 10) for i in range(pre_len)],
                         np.int32)
        out.extend(
            np.concatenate([pre, np.asarray(task.sample_problem().prompt,
                                            np.int32)])
            for _ in range(min(per, count - g * per)))
    return out
