"""Paper Table 1 + Figure 4: seconds/step, steps/s, runtime breakdown.

Wall-clock on this CPU container is not meaningful for TPU latency, so the
table combines (a) engine-measured acceptance rates and step statistics
with (b) the roofline latency model (serving/latency.py) at the paper's
model scales (Qwen2.5-Math 1.5B/7B + 7B PRM on our v5e constants).

The prefix-cache rows feed the roofline's prefill term with the prefix hit
fraction *measured* from a shared-preamble workload through the paged
engine's radix cache, so the reported prefill/sample times reflect
cross-request KV sharing.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks import common
from repro.config import get_config
from repro.serving.latency import HW_V5E, LatencyModel, ModelCost


def paper_latency_model():
    draft = get_config("qwen2.5-math-1.5b")
    target = get_config("qwen2.5-math-7b")
    prm = get_config("qwen2.5-math-prm-7b")

    def cost(cfg):
        kv = cfg.num_layers * cfg.kv_dim * 2 * 2  # bytes per token (bf16)
        return ModelCost(cfg.active_param_count(), kv)

    return LatencyModel(cost(draft), cost(target), cost(prm), HW_V5E)


def measured_prefix_fraction(fast: bool = False):
    """Run a shared-preamble workload through a paged+radix toy engine and
    return (hit_tokens / prefill-able prompt tokens, scheduler stats)."""
    from repro.config import GSIConfig
    from repro.serving import GSIScheduler, GSIServingEngine
    cfgs, params = common.get_triple()
    g = GSIConfig(n=2, beta=8.0, threshold_u=0.4, max_step_tokens=8,
                  max_steps=3, min_step_reward=0.0)
    eng = GSIServingEngine(*cfgs, *params, g, max_seq=112, paged=True,
                           page_size=16)
    sched = GSIScheduler(eng, capacity=2, prompt_pad_len=48)
    prompts = common.shared_prefix_prompts(6 if fast else 10, pre_len=33)
    for p in prompts:
        sched.submit(p, max_steps=2)
    sched.run(jax.random.PRNGKey(0))
    st = sched.prefix_stats()
    total = sum(int(p.size) - 1 for p in prompts)
    return st["hit_tokens"] / max(total, 1), st


def run(fast: bool = False):
    lm = paper_latency_model()
    ns = [4, 16]
    requests = 6 if fast else 16
    problems = common.sample_problems(requests, seed=3)
    # paper-scale step length / count (Table 1: ~10 steps, 512-token cap;
    # we use the measured synthetic acceptance rate per method)
    step_len, steps, ctx = 220.0, 10.5, 1200.0
    for n in ns:
        rates = {}
        for method in ["gsi", "rsd"]:
            res = common.eval_method(method, min(n, 4), problems, seed=4)
            rates[method] = res["accept_rate"]
        for method in ["gsi", "rsd", "sbon_s", "sbon_b"]:
            acc = rates.get(method, 1.0)
            t_step = lm.step_time(method=method, n=n, step_len=step_len,
                                  ctx_len=ctx, accept_rate=acc)
            common.emit(
                f"table1_latency/{method}/n{n}", t_step * 1e6,
                f"s_per_step={t_step:.3f};steps_per_s={1 / t_step:.2f};"
                f"accept={acc:.2f}")
        # headline: GSI faster than S-BoN(base)?
        t_gsi = lm.step_time(method="gsi", n=n, step_len=step_len,
                             ctx_len=ctx, accept_rate=rates["gsi"])
        t_b = lm.step_time(method="sbon_b", n=n, step_len=step_len,
                           ctx_len=ctx)
        common.emit(f"table1_speedup/n{n}", 0.0,
                    f"gsi_vs_sbon_b={t_b / t_gsi:.2f}x")

    # prefix cache: measured hit fraction (toy shared-preamble workload)
    # applied to the paper-scale prompt through the roofline prefill term
    frac, pstat = measured_prefix_fraction(fast)
    prompt_len = 512.0
    for n in ns:
        acc = rates["gsi"]
        t_cold = lm.prefill_time(prompt_len)
        t_warm = lm.prefill_time(prompt_len, prefix_hit_len=frac * prompt_len)
        s_cold = lm.sample_time(method="gsi", n=n, steps=steps,
                                step_len=step_len, accept_rate=acc,
                                prompt_len=prompt_len)
        s_warm = lm.sample_time(method="gsi", n=n, steps=steps,
                                step_len=step_len, accept_rate=acc,
                                prompt_len=prompt_len,
                                prefix_hit_len=frac * prompt_len)
        common.emit(
            f"table1_prefix/gsi/n{n}", s_warm * 1e6,
            f"measured_hit_frac={frac:.2f};"
            f"measured_hit_rate={pstat['hit_rate']:.2f};"
            f"prefill_s={t_cold:.4f};prefill_shared_s={t_warm:.4f};"
            f"prefill_speedup={t_cold / max(t_warm, 1e-12):.2f}x;"
            f"sample_speedup={s_cold / max(s_warm, 1e-12):.2f}x")

    # Figure 4: runtime breakdown across the three models for GSI
    n = 16
    acc = rates["gsi"]
    hw = lm.hw
    draft_t = step_len * lm.draft.decode_time(hw, ctx, n)
    score_t = lm.target.forward_time(hw, n * step_len)
    prm_t = lm.prm.forward_time(hw, n * step_len)
    resample_t = (1 - acc) * (step_len * lm.target.decode_time(hw, ctx, n)
                              + prm_t)
    total = draft_t + score_t + prm_t + resample_t
    common.emit(
        "fig4_breakdown/gsi_n16", total * 1e6,
        f"draft={draft_t / total:.2f};score={score_t / total:.2f};"
        f"prm={prm_t / total:.2f};resample={resample_t / total:.2f}")
