"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--only accuracy,...]

Tables covered (paper -> module):
    Table 2/3, Fig. 2   accuracy.py      method accuracy across n
    Table 1, Fig. 4     latency.py       s/step, steps/s, runtime breakdown
    Fig. 5              ablations.py     acceptance vs n (GSI vs RSD)
    Fig. 6-8            ablations.py     beta phase transition
    Fig. 9-11           ablations.py     threshold-u ablation
    Table 4             ablations.py     chi^2 estimates
    Theorem 1 (C.5)     ablations.py     KL vs bound table
    kernels             kernels_bench.py VMEM-tiling micro numbers
    serving (beyond-paper) throughput.py continuous-batching tokens/s
    memory (beyond-paper)  throughput.py paged-KV cache-memory report
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: tiny training budgets, implies --fast")
    ap.add_argument("--only", default=None,
                    help="comma list: accuracy,latency,ablations,kernels,"
                         "throughput,memory")
    args = ap.parse_args()

    from benchmarks import common
    args.fast = args.fast or args.smoke
    common.FAST = args.fast
    common.SMOKE = args.smoke
    only = set(args.only.split(",")) if args.only else None

    t0 = time.time()
    print("name,us_per_call,derived", flush=True)

    def want(name):
        return only is None or name in only

    if want("kernels"):
        from benchmarks import kernels_bench
        kernels_bench.run(args.fast)
    if want("ablations"):
        from benchmarks import ablations
        ablations.run(args.fast)
    if want("accuracy"):
        from benchmarks import accuracy
        accuracy.run(args.fast)
    if want("latency"):
        from benchmarks import latency
        latency.run(args.fast)
    if want("throughput"):
        from benchmarks import throughput
        throughput.run(args.fast)
    if want("memory"):
        # paged-KV cache-memory report: cheap enough for every CI smoke
        from benchmarks import throughput
        throughput.memory_report()

    print(f"# total {time.time() - t0:.1f}s, {len(__import__('benchmarks.common', fromlist=['all_rows']).all_rows())} rows",
          flush=True)


if __name__ == "__main__":
    main()
