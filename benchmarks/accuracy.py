"""Paper Tables 2/3 + Figure 2: accuracy of GSI vs RSD vs S-BoN across n.

Synthetic-task analogue (DESIGN.md §6): same four methods + the
no-rejection ablation, accuracy measured against the exact grader.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common

METHODS = ["gsi", "gsi_norej", "rsd", "sbon_s", "sbon_b"]


def run(fast: bool = False):
    ns = [1, 2] if fast else [1, 2, 4]
    requests = 8 if fast else 16
    problems = common.sample_problems(requests)
    results = {}
    for n in ns:
        for method in METHODS:
            t0 = time.perf_counter()
            res = common.eval_method(method, n, problems)
            wall = (time.perf_counter() - t0) * 1e6
            results[(method, n)] = res
            common.emit(
                f"table2_accuracy/{method}/n{n}", wall / requests,
                f"acc={res['accuracy']:.3f};accept={res['accept_rate']:.2f}")
    # paper claim (Fig. 2): GSI >= S-BoN(small) and GSI >= RSD at the
    # largest n (statistically, on the synthetic analogue)
    n = ns[-1]
    gsi = results[("gsi", n)]["accuracy"]
    sb_s = results[("sbon_s", n)]["accuracy"]
    rsd = results[("rsd", n)]["accuracy"]
    sb_b = results[("sbon_b", n)]["accuracy"]
    common.emit(f"table2_ordering/n{n}", 0.0,
                f"gsi={gsi:.3f};rsd={rsd:.3f};sbon_s={sb_s:.3f};"
                f"sbon_b={sb_b:.3f};gsi_ge_sbons={gsi >= sb_s}")
    return results
