"""SLO load generator: mixed traffic against the serving scheduler.

Where ``benchmarks/throughput.py`` measures scheduling disciplines under
a uniform synthetic gang, this harness offers *traffic*: an arrival
process (Poisson or bursty), a prompt-length mixture (short questions
vs long preamble-padded prompts), and a priority mix (a slice of
requests carries ``priority=1`` and a ``deadline_s`` SLO).  It reports
per-priority-class p50/p95/p99 TTFT and TPOT, SLO attainment, and the
scheduler's SLO counters (preemptions, resumes, deadline misses,
``prefill_commit_max``), as JSON compatible with the committed
``benchmarks/BENCH_SLO.json`` baseline.

``--check`` is the CI load-smoke gate.  It asserts, deterministically:

* **chunked prefill identity** — the same greedy (temperature 0)
  workload decoded with ``chunk_tokens`` on vs off yields bit-identical
  per-request tokens, while the largest single-step prefill commit drops
  from the full prompt length to the chunk budget (the decode-stall gap
  proxy: no single engine step ever commits more prompt tokens than the
  budget, so live decode is never stalled behind a long prompt);
* **preempt/resume round-trip** — a forced preemption (stepped scenario,
  no wall clock) pauses a low-priority request, page conservation
  ``free + referenced + cached == num_pages`` holds at the preempt point
  and after the drain, and the preempted request's final tokens are
  identical to its un-preempted greedy run;
* **SLO thresholds** — per-class p99 TTFT and SLO attainment from the
  timed run stay inside the committed ``BENCH_SLO.json`` envelope.

    PYTHONPATH=src python -m benchmarks.loadgen --smoke --check \
        --json out.json

``--restart`` runs the warm-restart scenario instead: kill the server
halfway through a greedy workload, warm-restart a fresh engine from the
radix-cache snapshot, and compare cold-vs-warm TTFT p95 and hit rates.
With ``--check`` it asserts the ``benchmarks/BENCH_WARM.json`` contract
(token identity with the uninterrupted run, warm hit rate, restored
page count, ledger conservation).
"""
from __future__ import annotations

import argparse
import json
import math
import pathlib

import jax
import numpy as np

from benchmarks import common
from repro.config import GSIConfig
from repro.serving import (GSIScheduler, GSIServingEngine, TokenStream,
                           merge_engine_stats)

BASELINE = pathlib.Path(__file__).with_name("BENCH_SLO.json")
BASELINE_WARM = pathlib.Path(__file__).with_name("BENCH_WARM.json")


# ----------------------------------------------------------------------
# Workload construction
# ----------------------------------------------------------------------
def arrivals(count: int, *, process: str, rate: float, burst: int,
             seed: int) -> np.ndarray:
    """Arrival offsets (seconds, sorted) for ``count`` requests.

    ``poisson``: iid exponential gaps at ``rate`` req/s.  ``bursty``:
    groups of ``burst`` simultaneous arrivals, groups spaced at the
    same mean inter-group rate — the adversarial case for admission
    (every burst hits the pool at once).
    """
    rng = np.random.default_rng(seed)
    if process == "poisson":
        gaps = rng.exponential(1.0 / rate, size=count)
        return np.cumsum(gaps)
    if process == "bursty":
        groups = -(-count // burst)
        starts = np.cumsum(rng.exponential(burst / rate, size=groups))
        return np.repeat(starts, burst)[:count]
    raise ValueError(f"unknown arrival process {process!r}")


def build_workload(count: int, *, seed: int = 7, process: str = "poisson",
                   rate: float = 40.0, burst: int = 4,
                   long_frac: float = 0.25, hi_frac: float = 0.25,
                   deadline_s: float = 300.0, pre_len: int = 34,
                   max_steps: int = 4):
    """``count`` requests with mixed lengths, priorities and deadlines.

    Long prompts carry a shared ``pre_len``-token preamble (so chunked
    prefill has something to chunk and the radix cache something to
    share); high-priority requests (``priority=1``) carry ``deadline_s``.
    Returns a list of dicts consumable by :func:`run_workload`.
    """
    task = common.get_task()
    task.rng = np.random.default_rng(seed)
    rng = np.random.default_rng(seed + 1)
    offs = arrivals(count, process=process, rate=rate, burst=burst,
                    seed=seed + 2)
    from repro.data.synthetic import D0
    pre = np.asarray([D0 + (i % 10) for i in range(pre_len)], np.int32)
    reqs = []
    for i in range(count):
        q = np.asarray(task.sample_problem().prompt, np.int32)
        long = rng.random() < long_frac
        hi = rng.random() < hi_frac
        reqs.append({
            "id": f"lg-{i}",
            "prompt": np.concatenate([pre, q]) if long else q,
            "arrival": float(offs[i]),
            "priority": 1 if hi else 0,
            "deadline_s": deadline_s if hi else None,
            "max_steps": max_steps,
        })
    return reqs


# ----------------------------------------------------------------------
# Driving + metrics
# ----------------------------------------------------------------------
def make_engine(*, max_steps: int = 4, page_size: int = 16,
                temperature: float = 0.0, num_pages: int = 0,
                **gkw) -> GSIServingEngine:
    """A fresh paged + radix-cache engine over the shared trained triple.

    Fresh per run: the page pool and radix index are engine-held host
    state, and cross-run cache warmth would contaminate TTFT numbers.
    Extra keywords override :class:`GSIConfig` fields.
    """
    cfgs, params = common.get_triple()
    kw = dict(n=2, beta=8.0, threshold_u=0.4, max_step_tokens=8,
              max_steps=max_steps, min_step_reward=0.0,
              temperature=temperature)
    kw.update(gkw)
    g = GSIConfig(**kw)
    return GSIServingEngine(*cfgs, *params, g, mode="gsi", max_seq=112,
                            paged=True, page_size=page_size,
                            num_pages=num_pages)


def _pct(xs, q):
    return float(np.percentile(xs, q)) if xs else float("nan")


def run_workload(reqs, *, capacity: int, chunk_tokens: int = 0,
                 sync: bool = True, realtime: bool = True,
                 stream_every: int = 0, seed: int = 0):
    """Serve ``reqs`` on one fresh engine; returns the metrics report.

    ``realtime=False`` zeroes every arrival offset (pure token-identity
    runs, no wall-clock dependence).  ``stream_every=k`` attaches a
    :class:`TokenStream` to every k-th request and verifies the streamed
    tokens reassemble that request's response exactly.
    """
    eng = make_engine(max_steps=max(r["max_steps"] for r in reqs))
    sched = GSIScheduler(eng, capacity=capacity, cache_aware=True,
                         sync=sync, chunk_tokens=chunk_tokens)
    streams = {}
    for i, r in enumerate(reqs):
        stream = None
        if stream_every and i % stream_every == 0:
            stream = streams[r["id"]] = TokenStream()
        sched.submit(r["prompt"], request_id=r["id"],
                     max_steps=r["max_steps"],
                     arrival_time=r["arrival"] if realtime else 0.0,
                     priority=r["priority"], deadline_s=r["deadline_s"],
                     stream=stream)
    out = sched.run(jax.random.PRNGKey(seed))
    for rid, ts in streams.items():
        events = list(ts)
        got = [t for e in events for t in e.tokens.tolist()]
        assert got == out[rid].tokens.tolist(), \
            f"stream drift for {rid}: {got} != {out[rid].tokens.tolist()}"
        assert events[-1].final, f"stream for {rid} never closed"
    stats = merge_engine_stats([sched.stats])
    classes = {}
    for prio in sorted({r["priority"] for r in reqs}):
        rs = [out[r["id"]] for r in reqs if r["priority"] == prio]
        ttft = [r.ttft for r in rs if not math.isnan(r.ttft)]
        tpot = [r.tpot for r in rs if not math.isnan(r.tpot)]
        with_slo = [r for r in rs if r.deadline_s is not None]
        classes[str(prio)] = {
            "requests": len(rs),
            "ttft_s": {q: _pct(ttft, p)
                       for q, p in (("p50", 50), ("p95", 95), ("p99", 99))},
            "tpot_s": {q: _pct(tpot, p)
                       for q, p in (("p50", 50), ("p95", 95), ("p99", 99))},
            "slo_requests": len(with_slo),
            "slo_attainment": (
                sum(not r.deadline_missed for r in with_slo)
                / len(with_slo)) if with_slo else None,
        }
    pager = eng.pager
    report = {
        "capacity": capacity, "chunk_tokens": chunk_tokens, "sync": sync,
        "requests": len(reqs),
        "engine_steps": sched.engine_steps,
        "classes": classes,
        "counters": {
            "preemptions": stats.preemptions,
            "resumes": stats.resumes,
            "deadline_misses": stats.deadline_misses,
            "prefill_commit_max": stats.prefill_commit_max,
            "prefix_hits": stats.prefix_hits,
        },
        "pages": {
            "free": pager.num_free, "cached": pager.num_cached,
            "total": eng.num_pages,
            "conserved": pager.num_free + pager.num_cached
            == eng.num_pages,
        },
    }
    report["token_lists"] = {r["id"]: out[r["id"]].tokens.tolist()
                             for r in reqs}
    return report


# ----------------------------------------------------------------------
# Deterministic forced-preemption scenario (no wall clock)
# ----------------------------------------------------------------------
def forced_preempt(*, page_size: int = 16):
    """Low-priority request decodes alone, then a high-priority long
    prompt lands on a capacity-1 pool: admission must pause the victim,
    serve the newcomer, and resume the victim from its published pages.

    Returns the two runs' token lists plus the invariant probes.
    """
    task = common.get_task()
    task.rng = np.random.default_rng(3)
    from repro.data.synthetic import D0
    # distinct preambles; the victim's must span >= 1 full page so its
    # pause publishes pages the resume can actually splice back
    pre_lo = np.asarray([D0 + (i % 10) for i in range(34)], np.int32)
    pre_hi = np.asarray([D0 + ((3 + i) % 10) for i in range(34)],
                        np.int32)
    low = np.concatenate([pre_lo,
                          np.asarray(task.sample_problem().prompt,
                                     np.int32)])
    high = np.concatenate([pre_hi,
                           np.asarray(task.sample_problem().prompt,
                                      np.int32)])
    # both runs pin the full step budget (no EOS, no reward early-stop):
    # the victim must still be decoding when the high-priority request
    # lands, whatever the trained triple would answer.  A roomy page
    # pool keeps the victim's published pages from being evicted before
    # its resume (the radix-splice probe needs them cached).
    mk = dict(eos_token_id=-1, min_step_reward=-1e9, num_pages=16)
    # baseline: both requests, roomy pool, no contention → no preemption
    eng = make_engine(**mk)
    sched = GSIScheduler(eng, capacity=2, cache_aware=True)
    sched.submit(low, request_id="low", max_steps=4, priority=0)
    sched.submit(high, request_id="high", max_steps=4, priority=1)
    base = {k: v.tokens.tolist()
            for k, v in sched.run(jax.random.PRNGKey(0)).items()}
    # contended: capacity 1; low runs first, high arrives mid-decode
    eng = make_engine(**mk)
    sched = GSIScheduler(eng, capacity=1, cache_aware=True)
    sched.submit(low, request_id="low", max_steps=4, priority=0)
    rng = jax.random.PRNGKey(0)
    rng, k1, k2 = jax.random.split(rng, 3)
    sched.step(k1, k2)
    sched.submit(high, request_id="high", max_steps=4, priority=1)
    rng, k1, k2 = jax.random.split(rng, 3)
    sched.step(k1, k2)              # admission preempts low for high
    conserved_mid = (eng.pager.num_free + eng.pager.num_referenced
                     + eng.pager.num_cached == eng.num_pages)
    while sched.queue or sched.pool.num_live or sched.has_pending:
        rng, k1, k2 = jax.random.split(rng, 3)
        sched.step(k1, k2)
    got = {k: v.tokens.tolist() for k, v in sched.responses.items()}
    return {
        "base": base, "got": got,
        "preemptions": sched.stats.preemptions,
        "resumes": sched.stats.resumes,
        "resume_prefix_hits": sched.stats.prefix_hits,
        "victim_preemptions": sched.responses["low"].preemptions,
        "conserved_mid": conserved_mid,
        "conserved_end": eng.pager.num_free + eng.pager.num_cached
        == eng.num_pages,
    }


# ----------------------------------------------------------------------
# Warm-restart scenario (--restart): kill mid-run, restore, compare
# ----------------------------------------------------------------------
def restart_scenario(*, capacity: int = 2, count: int = 8, seed: int = 7,
                     snapshot_path=None):
    """Kill the server halfway through a greedy workload and warm-restart
    it from a radix-cache snapshot.

    Three runs, all greedy (temperature 0) with arrival offsets zeroed:
    an *uninterrupted* reference over all ``count`` requests; a *cold
    phase* serving the first half on a fresh engine, after which the
    engine's hot cache is snapshotted (``save_cache``) and the process
    "dies"; and a *warm phase* serving the second half on a brand-new
    engine restored from the snapshot.  All prompts carry the shared
    long preamble, so the warm phase's admissions splice restored pages
    instead of re-prefilling.

    Reports cold-vs-warm TTFT p95 and radix hit-rates, whether the
    interrupted run's tokens are identical to the uninterrupted
    reference (greedy decoding makes trajectories batch-independent),
    the restored page count and the final conservation ledger.
    """
    reqs = build_workload(count, seed=seed, long_frac=1.0, hi_frac=0.0)
    half = count // 2

    def serve(engine, subset, *, snapshot=None):
        sched = GSIScheduler(engine, capacity=capacity, cache_aware=True)
        if snapshot is not None:
            sched.state = engine.load_cache(sched.state, snapshot)
        for r in subset:
            sched.submit(r["prompt"], request_id=r["id"],
                         max_steps=r["max_steps"], arrival_time=0.0)
        out = sched.run(jax.random.PRNGKey(seed))
        ttft = [out[r["id"]].ttft for r in subset
                if not math.isnan(out[r["id"]].ttft)]
        return sched, out, ttft

    # uninterrupted reference
    _, ref_out, _ = serve(make_engine(), reqs)
    ref = {r["id"]: ref_out[r["id"]].tokens.tolist() for r in reqs}
    # cold phase: first half, then the cache snapshot "survives the kill"
    cold_eng = make_engine()
    cold_sched, cold_out, cold_ttft = serve(cold_eng, reqs[:half])
    snapshot = cold_eng.save_cache(cold_sched.state, snapshot_path)
    # warm phase: fresh engine + restore, second half
    warm_eng = make_engine()
    warm_sched, warm_out, warm_ttft = serve(
        warm_eng, reqs[half:],
        snapshot=snapshot_path if snapshot_path is not None else snapshot)
    got = {r["id"]: cold_out[r["id"]].tokens.tolist()
           for r in reqs[:half]}
    got.update({r["id"]: warm_out[r["id"]].tokens.tolist()
                for r in reqs[half:]})
    pager = warm_eng.pager
    return {
        "requests": count, "capacity": capacity,
        "pages_restored": int(snapshot["pages"].shape[0]),
        "cold": {"ttft_p95_s": _pct(cold_ttft, 95),
                 "hit_rate": cold_sched.prefix_stats()["hit_rate"]},
        "warm": {"ttft_p95_s": _pct(warm_ttft, 95),
                 "hit_rate": warm_sched.prefix_stats()["hit_rate"],
                 "hits": warm_sched.prefix_stats()["hits"],
                 "pages_published_decode": warm_sched.prefix_stats()
                 ["pages_published_decode"]},
        "identical": got == ref,
        "conserved": pager.num_free + pager.num_cached
        == warm_eng.num_pages,
    }


def check_restart(rep, baseline_path):
    """Assert the --restart contract against BENCH_WARM.json."""
    with open(baseline_path) as fh:
        env = json.load(fh)["thresholds"]["loadgen"]
    assert rep["identical"], \
        "warm restart drifted: interrupted+restored tokens != " \
        "uninterrupted greedy run"
    assert rep["conserved"], "page ledger leaked across the restart"
    assert rep["pages_restored"] >= env["pages_restored_min"], \
        f"snapshot restored only {rep['pages_restored']} pages " \
        f"(min {env['pages_restored_min']})"
    assert rep["warm"]["hit_rate"] >= env["warm_hit_rate_min"], \
        f"warm hit rate {rep['warm']['hit_rate']:.2f} below " \
        f"{env['warm_hit_rate_min']} — the restore did not warm the cache"
    assert rep["warm"]["ttft_p95_s"] <= env["warm_ttft_p95_s_max"], \
        f"warm TTFT p95 {rep['warm']['ttft_p95_s']:.3f}s exceeds " \
        f"{env['warm_ttft_p95_s_max']}s"
    print("# loadgen restart check passed", flush=True)


# ----------------------------------------------------------------------
# The CI gate
# ----------------------------------------------------------------------
def check(report_chunked, report_plain, pre_report, baseline_path):
    """Assert the --check contract (see module docstring)."""
    # (a) chunked prefill is a pacing change, not an algorithm change
    assert report_chunked["token_lists"] == report_plain["token_lists"], \
        "chunked prefill drifted: tokens != unchunked greedy run"
    chunk = report_chunked["chunk_tokens"]
    got = report_chunked["counters"]["prefill_commit_max"]
    assert 0 < got <= chunk, \
        f"chunked run committed {got} prompt tokens in one step " \
        f"(budget {chunk})"
    plain = report_plain["counters"]["prefill_commit_max"]
    assert plain > chunk, \
        f"workload too short to exercise chunking: unchunked max " \
        f"single-step commit {plain} <= budget {chunk}"
    for rep in (report_chunked, report_plain):
        assert rep["pages"]["conserved"], f"page leak: {rep['pages']}"
    # (b) preempt == pause: identical tokens, conserved pages, radix resume
    assert pre_report["preemptions"] >= 1, "no preemption was forced"
    assert pre_report["resumes"] >= 1, "victim never resumed"
    assert pre_report["victim_preemptions"] >= 1, \
        "victim response does not record its preemption"
    assert pre_report["conserved_mid"] and pre_report["conserved_end"], \
        "page conservation violated across preempt/resume"
    assert pre_report["got"] == pre_report["base"], \
        f"preempt/resume drifted: {pre_report['got']} != " \
        f"{pre_report['base']}"
    assert pre_report["resume_prefix_hits"] >= 1, \
        "resume did not splice the victim's published pages"
    # (c) the committed SLO envelope
    with open(baseline_path) as fh:
        env = json.load(fh)
    for prio, th in env["thresholds"]["classes"].items():
        cls = report_chunked["classes"].get(prio)
        assert cls is not None, f"no class {prio} in the timed run"
        p99 = cls["ttft_s"]["p99"]
        assert p99 <= th["p99_ttft_s_max"], \
            f"class {prio} p99 TTFT {p99:.3f}s exceeds baseline " \
            f"{th['p99_ttft_s_max']}s"
        if th.get("slo_attainment_min") is not None:
            att = cls["slo_attainment"]
            assert att is not None and att >= th["slo_attainment_min"], \
                f"class {prio} SLO attainment {att} below baseline " \
                f"{th['slo_attainment_min']}"
    assert report_chunked["counters"]["prefill_commit_max"] <= \
        env["thresholds"]["chunk_commit_max"], "chunk budget regressed"
    print("# loadgen check passed", flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: tiny training budget, small workload")
    ap.add_argument("--check", action="store_true",
                    help="assert chunked==unchunked greedy tokens, "
                         "preemption page conservation + identity, and "
                         "the BENCH_SLO.json thresholds")
    ap.add_argument("--json", type=str, default="",
                    help="write the full report JSON here")
    ap.add_argument("--baseline", type=str, default=str(BASELINE))
    ap.add_argument("--requests", type=int, default=0)
    ap.add_argument("--capacity", type=int, default=3)
    ap.add_argument("--chunk-tokens", type=int, default=16)
    ap.add_argument("--process", choices=("poisson", "bursty"),
                    default="poisson")
    ap.add_argument("--rate", type=float, default=40.0,
                    help="mean arrival rate, requests/second")
    ap.add_argument("--burst", type=int, default=4)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--restart", action="store_true",
                    help="run only the warm-restart scenario: kill the "
                         "server mid-run, restore from a cache snapshot, "
                         "report cold-vs-warm TTFT p95 (with --check, "
                         "assert the BENCH_WARM.json contract)")
    args = ap.parse_args()
    args.fast = args.fast or args.smoke
    common.FAST, common.SMOKE = args.fast, args.smoke
    count = args.requests or (10 if args.smoke else 16 if args.fast
                              else 32)
    if args.restart:
        rep = restart_scenario(capacity=args.capacity, seed=args.seed)
        print(f"# restart: {rep['requests']} requests, "
              f"{rep['pages_restored']} pages restored", flush=True)
        print(f"cold ttft p95 = {rep['cold']['ttft_p95_s']:.3f}s "
              f"(hit rate {rep['cold']['hit_rate']:.2f})  "
              f"warm ttft p95 = {rep['warm']['ttft_p95_s']:.3f}s "
              f"(hit rate {rep['warm']['hit_rate']:.2f})  "
              f"identical = {rep['identical']}", flush=True)
        if args.check:
            check_restart(rep, BASELINE_WARM)
        if args.json:
            with open(args.json, "w") as fh:
                json.dump({"restart": rep}, fh, indent=2, sort_keys=True)
            print(f"# report written to {args.json}", flush=True)
        return
    reqs = build_workload(count, seed=args.seed, process=args.process,
                          rate=args.rate, burst=args.burst)
    print(f"# loadgen: {count} requests, {args.process} arrivals @ "
          f"{args.rate}/s, capacity {args.capacity}, chunk "
          f"{args.chunk_tokens}", flush=True)
    timed = run_workload(reqs, capacity=args.capacity,
                         chunk_tokens=args.chunk_tokens, sync=False,
                         stream_every=4, seed=args.seed)
    for prio, cls in timed["classes"].items():
        t, o = cls["ttft_s"], cls["tpot_s"]
        print(f"class {prio}: n={cls['requests']} "
              f"ttft p50/p95/p99 = {t['p50']:.3f}/{t['p95']:.3f}/"
              f"{t['p99']:.3f}s  tpot p50 = {o['p50'] * 1e3:.1f}ms  "
              f"slo_attainment = {cls['slo_attainment']}", flush=True)
    print(f"counters: {timed['counters']}  pages: {timed['pages']}",
          flush=True)
    report = {"timed": timed}
    if args.check:
        plain = run_workload(reqs, capacity=args.capacity, chunk_tokens=0,
                             realtime=False, seed=args.seed)
        chunked = run_workload(reqs, capacity=args.capacity,
                               chunk_tokens=args.chunk_tokens,
                               realtime=False, seed=args.seed)
        pre = forced_preempt()
        report["identity"] = {
            "chunked_commit_max":
                chunked["counters"]["prefill_commit_max"],
            "unchunked_commit_max":
                plain["counters"]["prefill_commit_max"],
        }
        report["preempt"] = {k: v for k, v in pre.items()
                             if k not in ("base", "got")}
        # the timed run carries the SLO numbers the envelope gates on,
        # plus the same chunk budget — check thresholds against it
        check({**chunked, "classes": timed["classes"]}, plain, pre,
              args.baseline)
    for rep in report.values():           # tokens are check-only payload
        rep.pop("token_lists", None)
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(report, fh, indent=2, sort_keys=True)
        print(f"# report written to {args.json}", flush=True)


if __name__ == "__main__":
    main()
