"""Continuous-batching serving throughput: tokens/s + latency percentiles.

Offered load: N >= 2x slot capacity requests with heterogeneous lengths
(natural EOS spread from the trained triple plus, for the budgeted rows,
deterministic per-request step budgets).  Three serving disciplines over
the *same* engine and jitted step functions:

    fixed_run    ``engine.run()`` in ceil(N/S) sequential gangs — the seed
                 discipline: a finished request holds its slot (and three
                 KV-cache rows) until the slowest request in its gang ends.
    gang         scheduler with ``continuous=False`` — same run-to-
                 completion discipline, but honouring per-request budgets.
    continuous   scheduler with ``continuous=True`` — finished slots are
                 freed and the next queued prompt is admitted on the
                 following engine step.

Every discipline decodes identical (capacity, ...) shapes, so per-step
cost is constant and the measured difference is pure scheduling.

    PYTHONPATH=src python -m benchmarks.throughput [--fast] [--check]
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from benchmarks import common
from repro.serving import GSIScheduler, GSIServingEngine

PAD = 0


def _prompt(problem):
    return np.asarray(problem.prompt, np.int32)


def _budgets(n, max_steps):
    """Deterministic heterogeneous step budgets, cycling short-to-long."""
    cycle = [1, 2, max(3, max_steps - 1), max_steps]
    return [cycle[i % len(cycle)] for i in range(n)]


def hetero_problems(count, seed=11, max_terms=5):
    """2..max_terms-term problems: response length scales with the term
    count, so the offered load has genuinely heterogeneous lengths."""
    from repro.data import SyntheticReasoningTask
    task = SyntheticReasoningTask(seed=seed, min_terms=2,
                                  max_terms=max_terms, max_value=9)
    return [task.sample_problem() for _ in range(count)]


def run_fixed(engine, problems, rng, *, capacity):
    """engine.run() over sequential gangs of `capacity` requests."""
    t0 = time.perf_counter()
    tokens, latencies = 0, []
    Lp = max(len(p.prompt) for p in problems)
    for lo in range(0, len(problems), capacity):
        batch = problems[lo:lo + capacity]
        prompts = np.zeros((capacity, Lp), np.int32)
        for i, p in enumerate(batch):
            prompts[i, :len(p.prompt)] = p.prompt
        rng, k = jax.random.split(rng)
        responses, _ = engine.run(prompts, k, collect_stats=False)
        batch_end = time.perf_counter() - t0
        for i in range(len(batch)):
            tokens += int(sum(s.size for s in responses[i]))
            latencies.append(batch_end)     # served when its gang completes
    wall = time.perf_counter() - t0
    return {"tokens": tokens, "wall": wall, "latencies": latencies}


def run_sched(engine, problems, rng, *, capacity, continuous,
              budgets=None):
    sched = GSIScheduler(engine, capacity=capacity,
                         continuous=continuous, prompt_pad_len=16)
    ids = []
    for i, p in enumerate(problems):
        ids.append(sched.submit(
            _prompt(p),
            max_steps=None if budgets is None else budgets[i]))
    t0 = time.perf_counter()
    results = sched.run(rng)
    wall = time.perf_counter() - t0
    tokens = sum(results[r].num_tokens for r in ids)
    return {"tokens": tokens, "wall": wall,
            "latencies": [results[r].latency for r in ids],
            "engine_steps": sched.engine_steps}


def _row(name, r):
    lat = np.sort(np.asarray(r["latencies"]))
    tps = r["tokens"] / max(r["wall"], 1e-9)
    common.emit(
        f"throughput/{name}", r["wall"] * 1e6,
        f"tokens={r['tokens']};tokens_per_s={tps:.1f};"
        f"p50_ms={np.percentile(lat, 50) * 1e3:.0f};"
        f"p95_ms={np.percentile(lat, 95) * 1e3:.0f}"
        + (f";engine_steps={r['engine_steps']}" if "engine_steps" in r
           else ""))
    return tps


def run(fast: bool = False, *, check: bool = False,
        capacity: int = 4, requests: int = 0):
    engine = common.get_engine("gsi", 2, max_steps=5)
    g = engine.gcfg
    n_req = requests or (3 * capacity if fast else 6 * capacity)
    problems = hetero_problems(n_req, seed=11)
    budgets = _budgets(n_req, g.max_steps)

    # warmup: compile every jitted phase (+ admission) outside the clock
    warm = problems[:capacity]
    run_fixed(engine, warm, jax.random.PRNGKey(0), capacity=capacity)
    run_sched(engine, warm, jax.random.PRNGKey(0), capacity=capacity,
              continuous=True, budgets=budgets[:capacity])

    rng = jax.random.PRNGKey(42)
    fixed = run_fixed(engine, problems, rng, capacity=capacity)
    tps_fixed = _row("fixed_run", fixed)

    # same EOS-governed workload through the scheduler disciplines
    cont_eos = run_sched(engine, problems, rng, capacity=capacity,
                         continuous=True)
    tps_cont_eos = _row("continuous", cont_eos)

    # deterministic heterogeneity: EOS disabled (same trained params), so
    # request length == its step budget exactly and the gang/continuous
    # difference is purely structural (engine steps: sum-of-gang-maxima vs
    # ~ceil(total-work / capacity))
    cfgs, params = common.get_triple()
    g2 = dataclasses.replace(g, eos_token_id=-1)
    engine2 = GSIServingEngine(*cfgs, *params, g2, mode="gsi",
                               max_seq=112)
    run_sched(engine2, warm, jax.random.PRNGKey(0), capacity=capacity,
              continuous=True, budgets=budgets[:capacity])   # compile
    gang = run_sched(engine2, problems, rng, capacity=capacity,
                     continuous=False, budgets=budgets)
    tps_gang = _row("gang_budgeted", gang)
    cont = run_sched(engine2, problems, rng, capacity=capacity,
                     continuous=True, budgets=budgets)
    tps_cont = _row("continuous_budgeted", cont)

    common.emit("throughput/speedup", 0.0,
                f"continuous_vs_fixed_run={tps_cont_eos / tps_fixed:.2f}x;"
                f"continuous_vs_gang={tps_cont / tps_gang:.2f}x;"
                f"gang_steps={gang['engine_steps']};"
                f"continuous_steps={cont['engine_steps']}")
    if check:
        # wall-clock-free structural check: fewer engine steps for the
        # same budgeted work (robust to noisy shared CI runners)
        assert cont["engine_steps"] < gang["engine_steps"], \
            "continuous batching must need fewer engine steps than gang"
        # the acceptance criterion: strictly higher aggregate tokens/s
        # than the fixed-batch run() discipline (large margin, ~1.5-1.8x)
        assert tps_cont_eos > tps_fixed, \
            f"continuous {tps_cont_eos:.1f} tok/s !> " \
            f"fixed run() {tps_fixed:.1f} tok/s"
        print("# throughput check passed", flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: tiny training budgets, implies --fast")
    ap.add_argument("--check", action="store_true",
                    help="assert continuous > fixed-batch tokens/s")
    ap.add_argument("--capacity", type=int, default=4)
    ap.add_argument("--requests", type=int, default=0)
    args = ap.parse_args()
    args.fast = args.fast or args.smoke
    common.FAST = args.fast
    common.SMOKE = args.smoke
    print("name,us_per_call,derived", flush=True)
    run(args.fast, check=args.check, capacity=args.capacity,
        requests=args.requests)


if __name__ == "__main__":
    main()
