"""Continuous-batching serving throughput: tokens/s + latency percentiles.

Offered load: N >= 2x slot capacity requests with heterogeneous lengths
(natural EOS spread from the trained triple plus, for the budgeted rows,
deterministic per-request step budgets).  Three serving disciplines over
the *same* engine and jitted step functions:

    fixed_run    ``engine.run()`` in ceil(N/S) sequential gangs — the seed
                 discipline: a finished request holds its slot (and three
                 KV-cache rows) until the slowest request in its gang ends.
    gang         scheduler with ``continuous=False`` — same run-to-
                 completion discipline, but honouring per-request budgets.
    continuous   scheduler with ``continuous=True`` — finished slots are
                 freed and the next queued prompt is admitted on the
                 following engine step.
    continuous_async  the same continuous scheduler with ``sync=False``:
                 one step ticket stays in flight and the previous step's
                 harvest + admission overlap the device execution.
                 ``--check`` asserts the pipeline is a pure re-ordering:
                 token streams bit-identical to ``continuous`` (same rng
                 keys per engine step, same slots), no more engine steps,
                 and a host/device overlap fraction > 0.

Every discipline decodes identical (capacity, ...) shapes, so per-step
cost is constant and the measured difference is pure scheduling.

A shared-prefix workload (common 33-token preamble + distinct questions)
additionally measures the radix prefix cache: hit-rate, pages reused and
prefill tokens skipped, with ``--check`` asserting the token streams are
identical to the no-sharing paged run and that sharing strictly reduces
prefill commits.

A multi-replica workload (two preamble groups, greedy decoding) runs the
same requests through one replica, two router-fronted replicas with
preamble-affinity routing, and two with round-robin: ``--check`` asserts
all three produce identical per-request tokens and that affinity's
aggregate radix hit-rate strictly beats round-robin's.  A warm-restart
row snapshots the single replica's radix cache, restores it into a
brand-new engine and replays the workload: ``--check`` asserts identical
tokens, strictly more cache-served admissions than the cold run, and the
``BENCH_WARM.json`` hit-rate envelope.

A tensor-parallel row (only when >= 2 devices are visible — real, or
forced host devices in the shard-smoke CI job) serves the EOS-governed
workload through a (data=1, model=2) submesh engine and reports the
per-device cache footprint; ``--check`` asserts bit-identical tokens to
the unsharded paged run and a strictly smaller per-device footprint.

A quantized-serving workload runs the same requests through a bf16-page
engine and an int8-page + int8-draft engine; ``--check`` asserts the
exact 2x page-capacity gain (int8 page payload is half bf16's) and the
``BENCH_QUANT.json`` statistical drift envelope (acceptance within 2pp,
mean reward within 1% of the fp engine), plus scale-slot/page-ledger
lockstep after the drain.

    PYTHONPATH=src python -m benchmarks.throughput [--fast] [--check]
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from benchmarks import common
from repro.serving import GSIScheduler, GSIServingEngine, ReplicaRouter

PAD = 0


def _prompt(problem):
    if hasattr(problem, "prompt"):
        return np.asarray(problem.prompt, np.int32)
    return np.asarray(problem, np.int32)   # raw token array workloads


def _budgets(n, max_steps):
    """Deterministic heterogeneous step budgets, cycling short-to-long."""
    cycle = [1, 2, max(3, max_steps - 1), max_steps]
    return [cycle[i % len(cycle)] for i in range(n)]


def hetero_problems(count, seed=11, max_terms=5):
    """2..max_terms-term problems: response length scales with the term
    count, so the offered load has genuinely heterogeneous lengths."""
    from repro.data import SyntheticReasoningTask
    task = SyntheticReasoningTask(seed=seed, min_terms=2,
                                  max_terms=max_terms, max_value=9)
    return [task.sample_problem() for _ in range(count)]


def run_fixed(engine, problems, rng, *, capacity, pad_len=0):
    """engine.run() over sequential gangs of `capacity` requests.

    ``pad_len`` pins the prompt width so a warmup over a subset compiles
    the same shapes as the timed full set (jit retrace must not land
    inside the clock)."""
    t0 = time.perf_counter()
    tokens, latencies = 0, []
    Lp = pad_len or max(len(p.prompt) for p in problems)
    for lo in range(0, len(problems), capacity):
        batch = problems[lo:lo + capacity]
        prompts = np.zeros((capacity, Lp), np.int32)
        for i, p in enumerate(batch):
            prompts[i, :len(p.prompt)] = p.prompt
        rng, k = jax.random.split(rng)
        responses, _ = engine.run(prompts, k, collect_stats=False)
        batch_end = time.perf_counter() - t0
        for i in range(len(batch)):
            tokens += int(sum(s.size for s in responses[i]))
            latencies.append(batch_end)     # served when its gang completes
    wall = time.perf_counter() - t0
    return {"tokens": tokens, "wall": wall, "latencies": latencies}


def run_sched(engine, problems, rng, *, capacity, continuous,
              budgets=None, sync=True, collect_stats=False):
    sched = GSIScheduler(engine, capacity=capacity,
                         continuous=continuous, prompt_pad_len=16,
                         sync=sync, collect_stats=collect_stats)
    ids = []
    for i, p in enumerate(problems):
        ids.append(sched.submit(
            _prompt(p),
            max_steps=None if budgets is None else budgets[i]))
    t0 = time.perf_counter()
    results = sched.run(rng)
    wall = time.perf_counter() - t0
    tokens = sum(results[r].num_tokens for r in ids)
    return {"tokens": tokens, "wall": wall,
            "latencies": [results[r].latency for r in ids],
            "engine_steps": sched.engine_steps,
            "prefix": sched.prefix_stats(),
            "pipeline": sched.pipeline_stats(),
            "stats": sched.stats,
            "token_lists": [results[r].tokens.tolist() for r in ids]}


def _row(name, r):
    lat = np.sort(np.asarray(r["latencies"]))
    tps = r["tokens"] / max(r["wall"], 1e-9)
    common.emit(
        f"throughput/{name}", r["wall"] * 1e6,
        f"tokens={r['tokens']};tokens_per_s={tps:.1f};"
        f"p50_ms={np.percentile(lat, 50) * 1e3:.0f};"
        f"p95_ms={np.percentile(lat, 95) * 1e3:.0f}"
        + (f";engine_steps={r['engine_steps']}" if "engine_steps" in r
           else ""))
    return tps


def _emit_mem(tag, rep):
    mb = 1.0 / (1024 * 1024)
    common.emit(
        f"memory/{tag}", 0.0,
        f"page_size={rep['page_size']};num_pages={rep['num_pages']};"
        f"dense_committed_mb={rep['dense_committed_bytes'] * mb:.2f};"
        f"paged_pool_mb={rep['paged_pool_bytes'] * mb:.2f};"
        f"dense_branch_mb={rep['dense_branch_bytes'] * mb:.2f};"
        f"paged_branch_mb={rep['paged_branch_bytes'] * mb:.2f};"
        f"branch_reduction={rep['branch_reduction']:.2f}x"
        + (f";pages_peak={rep['pages_peak']}" if "pages_peak" in rep
           else ""))


def memory_report(n: int = 4, capacity: int = 4, page_size: int = 16,
                  max_seq: int = 112):
    """Cache-memory report for an n-candidate paged engine (cheap:
    random-init params, one tiny scheduler workload to exercise the
    allocator; the branch-scratch numbers themselves are static).

    Returns the report dict so callers (CI smoke) can assert on it.
    """
    from repro.config import GSIConfig
    from repro.launch.serve import toy_triple
    from repro.models import build_model
    cfgs = toy_triple()
    params = tuple(build_model(c).init(jax.random.PRNGKey(i))
                   for i, c in enumerate(cfgs))
    g = GSIConfig(n=n, max_step_tokens=8, max_steps=3, min_step_reward=-1.0)
    eng = GSIServingEngine(*cfgs, *params, g, max_seq=max_seq, paged=True,
                           page_size=page_size)
    sched = GSIScheduler(eng, capacity=capacity, prompt_pad_len=16)
    for _ in range(capacity + 1):       # one draft phase + slot reuse
        sched.submit(np.array([5, 6, 4], np.int32), max_steps=1)
    sched.run(jax.random.PRNGKey(0))
    rep = eng.cache_memory_report(capacity)
    _emit_mem(f"paged_n{n}", rep)
    return rep


def run(fast: bool = False, *, check: bool = False,
        capacity: int = 4, requests: int = 0):
    engine = common.get_engine("gsi", 2, max_steps=5)
    g = engine.gcfg
    n_req = requests or (3 * capacity if fast else 6 * capacity)
    problems = hetero_problems(n_req, seed=11)
    budgets = _budgets(n_req, g.max_steps)

    # warmup: compile every jitted phase (+ admission) outside the clock,
    # at the full set's prompt width so the timed runs never retrace
    warm = problems[:capacity]
    full_pad = max(len(p.prompt) for p in problems)
    run_fixed(engine, warm, jax.random.PRNGKey(0), capacity=capacity,
              pad_len=full_pad)
    run_sched(engine, warm, jax.random.PRNGKey(0), capacity=capacity,
              continuous=True, budgets=budgets[:capacity])

    rng = jax.random.PRNGKey(42)
    fixed = run_fixed(engine, problems, rng, capacity=capacity)
    tps_fixed = _row("fixed_run", fixed)

    # same EOS-governed workload through the scheduler disciplines
    cont_eos = run_sched(engine, problems, rng, capacity=capacity,
                         continuous=True)
    tps_cont_eos = _row("continuous", cont_eos)

    # deterministic heterogeneity: EOS disabled (same trained params), so
    # request length == its step budget exactly and the gang/continuous
    # difference is purely structural (engine steps: sum-of-gang-maxima vs
    # ~ceil(total-work / capacity))
    cfgs, params = common.get_triple()
    g2 = dataclasses.replace(g, eos_token_id=-1)
    engine2 = GSIServingEngine(*cfgs, *params, g2, mode="gsi",
                               max_seq=112)
    run_sched(engine2, warm, jax.random.PRNGKey(0), capacity=capacity,
              continuous=True, budgets=budgets[:capacity])   # compile
    gang = run_sched(engine2, problems, rng, capacity=capacity,
                     continuous=False, budgets=budgets)
    tps_gang = _row("gang_budgeted", gang)
    cont = run_sched(engine2, problems, rng, capacity=capacity,
                     continuous=True, budgets=budgets)
    tps_cont = _row("continuous_budgeted", cont)

    # async pipeline on the same dense budgeted workload: one step ticket
    # in flight, harvest/admission overlapped with device decode.  The
    # pipeline preserves per-step rng keys, slot bindings and admission
    # order, so (even at sampling temperature > 0) the token streams must
    # be bit-identical to the synchronous run in no more engine steps.
    cont_async = run_sched(engine2, problems, rng, capacity=capacity,
                           continuous=True, budgets=budgets, sync=False)
    tps_cont_async = _row("continuous_async", cont_async)
    pipe = cont_async["pipeline"]
    common.emit(
        "throughput/async_overlap", 0.0,
        f"overlap_fraction={pipe['overlap_fraction']:.3f};"
        f"overlap_host_ms={pipe['overlap_host_s'] * 1e3:.1f};"
        f"serial_host_ms={pipe['serial_host_s'] * 1e3:.1f};"
        f"materialize_wait_ms={pipe['materialize_wait_s'] * 1e3:.1f};"
        f"async_steps={cont_async['engine_steps']};"
        f"sync_steps={cont['engine_steps']};"
        f"async_vs_sync={tps_cont_async / tps_cont:.2f}x")

    common.emit("throughput/speedup", 0.0,
                f"continuous_vs_fixed_run={tps_cont_eos / tps_fixed:.2f}x;"
                f"continuous_vs_gang={tps_cont / tps_gang:.2f}x;"
                f"gang_steps={gang['engine_steps']};"
                f"continuous_steps={cont['engine_steps']}")

    # paged KV cache: same params and rng stream through the paged engine
    # must reproduce the dense continuous run token-for-token, while the
    # candidate-branch scratch drops from n full cache copies to
    # n * span copy-on-write pages per slot
    engine_paged = GSIServingEngine(*cfgs, *params, g, mode="gsi",
                                    max_seq=112, paged=True, page_size=16)
    run_sched(engine_paged, warm, jax.random.PRNGKey(0), capacity=capacity,
              continuous=True, budgets=budgets[:capacity])   # compile
    paged = run_sched(engine_paged, problems, rng, capacity=capacity,
                      continuous=True)
    _row("continuous_paged", paged)
    _emit_mem(f"paged_n{g.n}", engine_paged.cache_memory_report(capacity))
    # n=4 branch-scratch comparison is static arithmetic — build the
    # engine object only, never a state or a jitted phase
    eng4 = GSIServingEngine(*cfgs, *params, dataclasses.replace(g, n=4),
                            mode="gsi", max_seq=112, paged=True,
                            page_size=16)
    rep4 = eng4.cache_memory_report(capacity)
    _emit_mem("paged_n4", rep4)

    # shared-prefix workload: every request carries the same 33-token
    # preamble (two full 16-token pages + one), so the radix prefix cache
    # shares 32 prefill tokens per request after the first admission batch.
    # Token streams must be identical with sharing on vs off — the cache is
    # a prefill shortcut, not an algorithm change.  NOTE on wall-clock: the
    # jitted admit scans the full padded prompt width regardless of hit
    # (jit-stable shapes), so on these tiny CPU shapes sharing shows up in
    # the deterministic counters below (prefill commits, pages reused) and
    # in page-write traffic — the accelerator-side prefill-time savings are
    # modeled by the roofline rows in benchmarks/latency.py.
    shared = common.shared_prefix_prompts(2 * capacity, pre_len=33)
    eng_off = GSIServingEngine(*cfgs, *params, g, mode="gsi", max_seq=112,
                               paged=True, page_size=16,
                               prefix_cache=False)
    run_sched(eng_off, shared[:capacity], jax.random.PRNGKey(0),
              capacity=capacity, continuous=True)              # compile
    pfx_off = run_sched(eng_off, shared, rng, capacity=capacity,
                        continuous=True)
    _row("shared_prefix_off", pfx_off)
    # engine_paged has the radix cache on (the default for paged engines);
    # warm it at the shared-prefix prompt width too — each run_sched builds
    # a fresh scheduler/state, so the warm-up's radix index is discarded
    # and the timed run still starts from an empty cache
    run_sched(engine_paged, shared[:capacity], jax.random.PRNGKey(0),
              capacity=capacity, continuous=True)              # compile
    pfx_on = run_sched(engine_paged, shared, rng, capacity=capacity,
                       continuous=True)
    _row("shared_prefix_on", pfx_on)
    # async over paged + prefix cache: radix lookups, page claims and
    # eviction all ride the pipelined host loop — placement, hits and
    # tokens must stay bit-identical to the synchronous run
    pfx_async = run_sched(engine_paged, shared, rng, capacity=capacity,
                          continuous=True, sync=False)
    _row("shared_prefix_async", pfx_async)
    pstat = pfx_on["prefix"]
    common.emit(
        "throughput/prefix_cache", 0.0,
        f"hit_rate={pstat['hit_rate']:.2f};hits={pstat['hits']};"
        f"pages_reused={pstat['pages_reused']};"
        f"prefill_tokens_skipped={pstat['hit_tokens']};"
        f"prefill_tokens={pstat['prefill_tokens']};"
        f"no_share_prefill_tokens={pfx_off['prefix']['prefill_tokens']};"
        f"pages_evicted={pstat['pages_evicted']};"
        f"pages_cached={pstat['pages_cached']}")

    # multi-replica data-parallel serving: independent replicas (own page
    # pool + radix index each) behind the preamble-affinity router, vs
    # round-robin on the same two-preamble workload.  Greedy decoding
    # (temperature=0) makes every request's trajectory a function of its
    # prompt + budget only — independent of slot, step count, rng and
    # batch composition — so the token streams must be identical whatever
    # the replica count or routing policy; routing affects only locality,
    # i.e. each replica's radix hit-rate.  Preamble groups are laid out in
    # blocks, so round-robin provably spreads every group across both
    # replicas (one cold miss per (group, replica) pair) while affinity
    # keeps each group on one replica (one cold miss per group).
    g0 = dataclasses.replace(g, temperature=0.0)
    mr_prompts = common.shared_prefix_prompts(8, pre_len=33, groups=2)
    mr_budgets = _budgets(len(mr_prompts), g0.max_steps)

    def mr_submit(frontend):
        for i, p in enumerate(mr_prompts):
            frontend.submit(p, request_id=f"mr-{i}",
                            max_steps=mr_budgets[i])

    def mr_run(frontend, tag):
        mr_submit(frontend)
        t0 = time.perf_counter()
        out = frontend.run(jax.random.PRNGKey(7))
        r = {"tokens": sum(v.num_tokens for v in out.values()),
             "wall": time.perf_counter() - t0,
             "latencies": [v.latency for v in out.values()],
             "engine_steps": frontend.engine_steps,
             "prefix": frontend.prefix_stats(),
             "token_lists": {k: v.tokens.tolist() for k, v in out.items()}}
        _row(tag, r)
        return r

    single_eng = GSIServingEngine(*cfgs, *params, g0, mode="gsi",
                                  max_seq=112, paged=True, page_size=16)
    single_sched = GSIScheduler(single_eng, capacity=1)
    mr_single = mr_run(single_sched, "replicas1_single")
    replica_engines = [
        GSIServingEngine(*cfgs, *params, g0, mode="gsi", max_seq=112,
                         paged=True, page_size=16) for _ in range(2)]
    # skew=None: pure affinity for a deterministic hit-rate comparison.
    # Warm the router, then fresh_state() — the timed phase must start
    # from empty caches AND zeroed counters (the stale-hit-rate fix).
    # threaded=False: the affinity/round-robin rows are the *sequential*
    # baselines the threaded async fleet row is compared against
    aff_router = ReplicaRouter(replica_engines, capacity=1,
                               policy="affinity", skew=None,
                               threaded=False)
    for i, p in enumerate(mr_prompts[:2]):
        aff_router.submit(p, request_id=f"warm-{i}", max_steps=1)
    aff_router.run(jax.random.PRNGKey(3))
    aff_router.fresh_state()
    mr_aff = mr_run(aff_router, "replicas2_affinity")
    # same engines, new router: each replica scheduler rebuilds its
    # engine state (page pool + radix index reset, jits reused)
    rr_router = ReplicaRouter(replica_engines, capacity=1,
                              policy="round_robin", threaded=False)
    mr_rr = mr_run(rr_router, "replicas2_round_robin")
    # async fleet: thread-per-replica loop driving pipelined schedulers
    # (each replica owns its engine/state/pool, so threads share no
    # device state).  Greedy decoding again: tokens must be identical
    # whatever the thread schedule.
    async_router = ReplicaRouter(replica_engines, capacity=1,
                                 policy="affinity", skew=None,
                                 sync=False, threaded=True)
    mr_async = mr_run(async_router, "replicas2_async")
    aps, rps = mr_aff["prefix"], mr_rr["prefix"]
    common.emit(
        "throughput/replica_routing", 0.0,
        f"affinity_hit_rate={aps['hit_rate']:.2f};"
        f"round_robin_hit_rate={rps['hit_rate']:.2f};"
        f"affinity_hits={aps['hits']};round_robin_hits={rps['hits']};"
        f"affinity_prefill_tokens={aps['prefill_tokens']};"
        f"round_robin_prefill_tokens={rps['prefill_tokens']};"
        f"per_replica_hits="
        f"{'/'.join(str(p['hits']) for p in aps['per_replica'])}(aff)_"
        f"{'/'.join(str(p['hits']) for p in rps['per_replica'])}(rr)")

    # warm restart: snapshot the single replica's radix cache after its
    # cold grouped-preamble run, restore it into a brand-new engine, and
    # replay the same workload.  A restart is a state-transfer change,
    # not an algorithm change: every admission must splice restored
    # pages and greedy tokens must match the cold run bit-for-bit.
    wr_snap = single_eng.save_cache(single_sched.state)
    wr_eng = GSIServingEngine(*cfgs, *params, g0, mode="gsi",
                              max_seq=112, paged=True, page_size=16)
    wr_sched = GSIScheduler(wr_eng, capacity=1)
    wr_sched.state = wr_eng.load_cache(wr_sched.state, wr_snap)
    mr_warm = mr_run(wr_sched, "replicas1_warm_restart")
    wps = mr_warm["prefix"]
    common.emit(
        "throughput/warm_restart", 0.0,
        f"pages_restored={int(wr_snap['pages'].shape[0])};"
        f"warm_hit_rate={wps['hit_rate']:.2f};warm_hits={wps['hits']};"
        f"cold_hit_rate={mr_single['prefix']['hit_rate']:.2f};"
        f"cold_hits={mr_single['prefix']['hits']}")

    # quantized KV pages + int8 draft weights: the same workload and rng
    # through a bf16-page engine (the capacity baseline: plain cast, no
    # scales) and an int8-page + quantized-draft engine.  Quantization
    # legitimately perturbs logits, so the contract is statistical —
    # bounded acceptance-rate and mean-reward drift vs the fp engine —
    # plus an *exact* storage claim: an int8 page's payload is half a
    # bf16 page's, so equal HBM holds exactly 2x the pages.
    fp_q = run_sched(engine_paged, problems, rng, capacity=capacity,
                     continuous=True, collect_stats=True)
    eng_bf16 = GSIServingEngine(*cfgs, *params, g, mode="gsi",
                                max_seq=112, paged=True, page_size=16,
                                kv_dtype="bf16")
    run_sched(eng_bf16, warm, jax.random.PRNGKey(0), capacity=capacity,
              continuous=True)                                # compile
    bf16_q = run_sched(eng_bf16, problems, rng, capacity=capacity,
                       continuous=True, collect_stats=True)
    _row("continuous_kv_bf16", bf16_q)
    eng_int8 = GSIServingEngine(*cfgs, *params, g, mode="gsi",
                                max_seq=112, paged=True, page_size=16,
                                kv_dtype="int8", quantize_draft=True)
    run_sched(eng_int8, warm, jax.random.PRNGKey(0), capacity=capacity,
              continuous=True)                                # compile
    int8_q = run_sched(eng_int8, problems, rng, capacity=capacity,
                       continuous=True, collect_stats=True)
    _row("continuous_kv_int8", int8_q)
    rep_bf16 = eng_bf16.cache_memory_report(capacity)
    rep_int8 = eng_int8.cache_memory_report(capacity)
    _emit_mem("paged_kv_bf16", rep_bf16)
    _emit_mem("paged_kv_int8", rep_int8)
    cap_ratio = rep_bf16["bytes_per_page"] / rep_int8["bytes_per_page"]
    accept_fp = fp_q["stats"].accept_rate
    accept_i8 = int8_q["stats"].accept_rate
    reward_fp = fp_q["stats"].trace_mean("raw_rewards")
    reward_i8 = int8_q["stats"].trace_mean("raw_rewards")
    # tensor-parallel sharded serving: when >= 2 devices are visible
    # (real, or XLA_FLAGS-forced host devices in the shard-smoke CI job)
    # the same EOS-governed workload runs through a (data=1, model=2)
    # submesh engine — target weights and target KV pool sharded over
    # the 'model' axis, draft/PRM replicated, collect-then-compute
    # all_gathers keeping tokens BIT-IDENTICAL to the unsharded paged
    # run — and reports the per-device cache footprint.
    tp_run = rep_tp = None
    if len(jax.devices()) >= 2:
        from repro.launch.mesh import carve_submeshes
        eng_tp = GSIServingEngine(*cfgs, *params, g, mode="gsi",
                                  max_seq=112, paged=True, page_size=16,
                                  mesh=carve_submeshes(1, (1, 2))[0])
        run_sched(eng_tp, warm, jax.random.PRNGKey(0), capacity=capacity,
                  continuous=True)                            # compile
        tp_run = run_sched(eng_tp, problems, rng, capacity=capacity,
                           continuous=True)
        _row("continuous_sharded_tp2", tp_run)
        rep_tp = eng_tp.cache_memory_report(capacity)
        _emit_mem("paged_sharded_tp2", rep_tp)
        common.emit(
            "throughput/sharded", 0.0,
            f"tp={eng_tp.tp};devices={rep_tp['devices']};"
            f"bytes_per_device={rep_tp['bytes_per_device']};"
            f"capacity_tokens_per_device="
            f"{rep_tp['capacity_tokens_per_device']};"
            f"total_capacity_bytes={rep_tp['capacity_bytes']}")

    from repro.serving import quantized_fraction
    common.emit(
        "throughput/quant_drift", 0.0,
        f"capacity_ratio_int8_vs_bf16={cap_ratio:.2f};"
        f"int8_page_bytes={rep_int8['bytes_per_page']};"
        f"scale_bytes_per_page={rep_int8['scale_bytes_per_page']};"
        f"bf16_page_bytes={rep_bf16['bytes_per_page']};"
        f"accept_fp={accept_fp:.3f};accept_int8={accept_i8:.3f};"
        f"reward_fp={reward_fp:.4f};reward_int8={reward_i8:.4f};"
        f"draft_weights_quantized="
        f"{quantized_fraction(cfgs[0], params[0]):.2f}")

    if check:
        import json
        import pathlib
        env = json.loads(pathlib.Path(__file__).with_name(
            "BENCH_QUANT.json").read_text())["thresholds"]
        # exact storage claim: int8 page payload is byte-for-byte half a
        # bf16 page's -> equal HBM budget holds exactly 2x the pages
        want = env["capacity_ratio_int8_vs_bf16"]
        assert cap_ratio == want, \
            f"int8 capacity gain {cap_ratio}x != exact {want}x " \
            f"({rep_bf16['bytes_per_page']} vs " \
            f"{rep_int8['bytes_per_page']} B/page)"
        # statistical accuracy contract vs the fp engine (same workload,
        # same rng): bounded acceptance and reward drift, NOT token
        # identity — quantization legitimately perturbs logits.  The pp
        # envelope binds at scale; on smoke-sized workloads a single
        # flipped accept/reject decision exceeds it, so the gate allows
        # up to two flipped decisions (200/N pp) before failing
        drift_pp = abs(accept_i8 - accept_fp) * 100
        decisions = max(1, int8_q["stats"].decisions)
        allowed_pp = max(env["accept_drift_pp_max"], 200.0 / decisions)
        assert drift_pp <= allowed_pp, \
            f"int8 acceptance drifted {drift_pp:.1f}pp from fp " \
            f"({accept_i8:.3f} vs {accept_fp:.3f}; " \
            f"allowed {allowed_pp:.1f}pp at {decisions} decisions)"
        drift_rw = abs(reward_i8 - reward_fp) / max(abs(reward_fp), 1e-9)
        assert drift_rw <= env["reward_drift_rel_max"], \
            f"int8 mean reward drifted {drift_rw:.3f} (rel) from fp " \
            f"({reward_i8:.4f} vs {reward_fp:.4f})"
        # ledger: quantized pages drain like fp pages, scales in lockstep
        pool = eng_int8.pager
        assert pool.num_free + pool.num_referenced + pool.num_cached \
            == eng_int8.num_pages, "quantized page ledger leaked"
        assert pool.scale_slots == set(pool.refcount) | pool.cached, \
            "scale slots out of lockstep with page lifecycle"
        # the paged cache is a layout change, not an algorithm change
        assert paged["tokens"] == cont_eos["tokens"], \
            f"paged engine drifted: {paged['tokens']} tokens != dense " \
            f"{cont_eos['tokens']}"
        # tensor parallelism is a placement change, not an algorithm
        # change: the (1,2)-submesh engine must reproduce the unsharded
        # paged run token-for-token, with a genuinely smaller per-device
        # KV footprint (the target pool's kv-head axis is split 2-way)
        if tp_run is not None:
            shard_env = json.loads(pathlib.Path(__file__).with_name(
                "BENCH_SHARD.json").read_text())["thresholds"]
            assert tp_run["token_lists"] == paged["token_lists"], \
                "sharded engine drifted from the unsharded paged run"
            assert rep_tp["devices"] == shard_env["devices"], \
                rep_tp["devices"]
            dev_ratio = rep_tp["bytes_per_device"] / rep_tp["capacity_bytes"]
            assert dev_ratio <= shard_env["per_device_bytes_ratio_max"], \
                f"per-device cache footprint ratio {dev_ratio:.3f} " \
                f"exceeds {shard_env['per_device_bytes_ratio_max']}"
        # candidate-branch scratch HBM must shrink for n >= 4
        assert rep4["paged_branch_bytes"] < rep4["dense_branch_bytes"], \
            "paged branch scratch must undercut dense repeat_cache at n=4"
        # wall-clock-free structural checks only: with the warmup now
        # compiling the fixed discipline at the timed prompt width (no
        # retrace inside its clock), tiny smoke workloads are dominated
        # by admission-commit overhead and the wall-clock ratios above
        # are reported, not asserted (noisy shared CI runners).  The
        # scheduling win is the step count: the same budgeted request
        # set in strictly fewer engine steps than the gang discipline.
        assert cont["engine_steps"] < gang["engine_steps"], \
            "continuous batching must need fewer engine steps than gang"
        # prefix sharing is a prefill shortcut, not an algorithm change:
        # every request's token stream must be identical with the radix
        # cache on vs off, while strictly fewer prompt tokens are
        # prefill-committed and at least one page is actually reused
        assert pfx_on["token_lists"] == pfx_off["token_lists"], \
            "prefix sharing drifted: shared-prefix tokens != no-sharing run"
        assert pstat["hit_rate"] > 0 and pstat["pages_reused"] > 0, \
            "shared-prefix workload must hit the radix cache"
        assert pstat["prefill_tokens"] < \
            pfx_off["prefix"]["prefill_tokens"], \
            "prefix sharing must commit strictly fewer prefill tokens"
        # the async pipeline is a re-ordering of host work, not an
        # algorithm change: bit-identical tokens on the dense budgeted
        # workload (sampling temperature > 0 — the strictest possible
        # rng/slot/admission parity check) and on paged + prefix cache,
        # in no more engine steps, with real host/device overlap
        assert cont_async["token_lists"] == cont["token_lists"], \
            "async pipeline drifted: continuous_async tokens != sync"
        assert cont_async["engine_steps"] <= cont["engine_steps"], \
            f"async used more engine steps ({cont_async['engine_steps']}" \
            f" > {cont['engine_steps']})"
        assert pfx_async["token_lists"] == pfx_on["token_lists"], \
            "async pipeline drifted on the paged+prefix workload"
        assert pfx_async["engine_steps"] <= pfx_on["engine_steps"], \
            "async used more engine steps on the paged+prefix workload"
        assert pipe["overlap_fraction"] > 0, \
            "async pipeline reported zero host/device overlap"
        # multi-replica serving is a placement change, not an algorithm
        # change: under greedy decoding every routing must reproduce the
        # single-replica token streams request-for-request — including
        # the thread-per-replica async fleet loop
        assert mr_single["token_lists"] == mr_aff["token_lists"] \
            == mr_rr["token_lists"], \
            "multi-replica routing drifted from the single-replica run"
        assert mr_async["token_lists"] == mr_single["token_lists"], \
            "async fleet loop drifted from the single-replica run"
        # preamble affinity must beat locality-blind round-robin on
        # aggregate radix hit-rate for the grouped-preamble workload
        assert aps["hit_rate"] > rps["hit_rate"], \
            f"affinity hit-rate {aps['hit_rate']:.2f} must beat " \
            f"round-robin {rps['hit_rate']:.2f}"
        # fresh_state() zeroed the warm-up's counters: the timed affinity
        # phase reports exactly its own admissions (stale-hit-rate fix)
        assert aps["queries"] == len(mr_prompts), \
            f"stale prefix counters: {aps['queries']} queries reported " \
            f"for {len(mr_prompts)} admissions"
        # warm restart: the restored cache must reproduce the cold run's
        # greedy tokens while serving strictly more admissions from
        # cache, inside the committed BENCH_WARM.json envelope
        warm_env = json.loads(pathlib.Path(__file__).with_name(
            "BENCH_WARM.json").read_text())["thresholds"]["throughput"]
        assert mr_warm["token_lists"] == mr_single["token_lists"], \
            "warm-restarted engine drifted from the cold run"
        assert wps["hits"] > mr_single["prefix"]["hits"], \
            f"restored cache served no more admissions than the cold " \
            f"run ({wps['hits']} <= {mr_single['prefix']['hits']})"
        assert wps["hit_rate"] >= warm_env["warm_hit_rate_min"], \
            f"warm hit rate {wps['hit_rate']:.2f} below envelope " \
            f"{warm_env['warm_hit_rate_min']}"
        print("# throughput check passed", flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: tiny training budgets, implies --fast")
    ap.add_argument("--check", action="store_true",
                    help="assert continuous < gang engine steps, paged == "
                         "dense tokens, paged scratch < dense at n=4, "
                         "prefix sharing: identical tokens, hit-rate > 0, "
                         "strictly fewer prefill commits, multi-replica: "
                         "single == routed tokens, affinity hit-rate > "
                         "round-robin, and async pipeline: sync == async "
                         "tokens bit-identically (dense and paged+prefix, "
                         "1 and 2 replicas), no more engine steps, "
                         "overlap fraction > 0, quantized KV: exact "
                         "2x int8-vs-bf16 page capacity + the "
                         "BENCH_QUANT.json accept/reward drift envelope, "
                         "and warm restart: snapshot/restore reproduces "
                         "the cold run inside BENCH_WARM.json")
    ap.add_argument("--capacity", type=int, default=4)
    ap.add_argument("--requests", type=int, default=0)
    args = ap.parse_args()
    args.fast = args.fast or args.smoke
    common.FAST = args.fast
    common.SMOKE = args.smoke
    print("name,us_per_call,derived", flush=True)
    run(args.fast, check=args.check, capacity=args.capacity,
        requests=args.requests)


if __name__ == "__main__":
    main()
