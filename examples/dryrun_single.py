"""Example: lower + roofline one (architecture x shape) on the production
mesh without hardware.  Thin wrapper over repro.launch.dryrun.

    PYTHONPATH=src python examples/dryrun_single.py --arch gemma3-1b \
        --shape decode_32k --mesh single
"""
import argparse
import json


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    args = ap.parse_args()

    # dryrun sets XLA_FLAGS before importing jax — import it first
    from repro.launch.dryrun import run_one
    rec = run_one(args.arch, args.shape, args.mesh)
    print(json.dumps({k: v for k, v in rec.items() if k != "traceback"},
                     indent=2, default=str))


if __name__ == "__main__":
    main()
