"""Training example: fit a language model + PRM on the reasoning task with
the full pipeline (data gen -> prefetch -> AdamW/cosine -> checkpoint).

By default trains a reduced SmolLM variant (CPU-friendly); pass
``--full`` on real hardware to train the actual smollm-135m config for a
few hundred steps (deliverable (b)'s training driver — the end-to-end
serving driver is examples/serve_gsi.py, matching the paper's kind).

    PYTHONPATH=src python examples/train_reasoning.py --steps 300
"""
import argparse
import dataclasses

import jax

from repro.checkpoint import save_checkpoint
from repro.config import TrainConfig, get_config, reduced_config
from repro.data import SyntheticReasoningTask
from repro.train import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--full", action="store_true",
                    help="train the real smollm-135m config (needs TPU)")
    ap.add_argument("--ckpt", default="/tmp/reasoning_lm.msgpack")
    args = ap.parse_args()

    task = SyntheticReasoningTask(seed=0)
    cfg = get_config("smollm-135m")
    if not args.full:
        cfg = dataclasses.replace(
            reduced_config(cfg), vocab_size=16, d_model=128, head_dim=32,
            num_layers=4, d_ff=384)
    tcfg = TrainConfig(learning_rate=1e-3, total_steps=args.steps,
                       warmup_steps=max(10, args.steps // 20))

    print(f"training {cfg.name}: {cfg.param_count() / 1e6:.1f}M params")
    tr = Trainer(cfg, tcfg)
    hist = tr.fit((task.lm_batch(args.batch, args.seq) for _ in iter(int, 1)),
                  steps=args.steps, log_every=max(1, args.steps // 10))
    for h in hist:
        print(f"  step {h['step']:5d}  loss {h['loss']:.4f}")
    assert hist[-1]["loss"] < hist[0]["loss"]

    # PRM on the same task
    prm_cfg = dataclasses.replace(cfg, name=cfg.name + "-prm",
                                  reward_head=True)
    trp = Trainer(prm_cfg, tcfg, prm=True)
    hp = trp.fit((task.prm_batch(args.batch, args.seq)
                  for _ in iter(int, 1)),
                 steps=args.steps, log_every=max(1, args.steps // 10))
    print(f"PRM loss {hp[0]['loss']:.4f} -> {hp[-1]['loss']:.4f}")

    save_checkpoint(args.ckpt, tr.params)
    print(f"saved LM checkpoint to {args.ckpt}")


if __name__ == "__main__":
    main()
