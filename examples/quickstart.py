"""Quickstart: GSI on the exact toy environment in 60 seconds.

Shows the paper's core objects with everything in closed form:
the tilted policy pi_{beta,B}, the tilted rewards r~, Algorithm 1, and the
Theorem 1 KL bound checked numerically.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import ToyEnv, theory

env = ToyEnv(m=12, seed=0)
beta, u = 1.0, 0.5

print("pi_B      :", jnp.round(env.pi_B, 3))
print("pi_S      :", jnp.round(env.pi_S, 3))
print("rewards r :", jnp.round(env.r, 3))
print(f"chi^2(pi_B||pi_S) = {float(env.chi2):.3f}")

tilted = env.tilted(beta)
print("\noptimal tilted policy pi_beta,B:", jnp.round(tilted, 3))

print(f"\nGSI (Algorithm 1) vs Theorem 1 bound, beta={beta}, u={u}:")
print(f"{'n':>5} {'KL(pi_bB || GSI~)':>18} {'Thm-1 bound':>12} "
      f"{'accept%':>8} {'E[r*] gap':>10}")
for n in [1, 4, 16, 64]:
    trials = min(150_000, 2_400_000 // n)
    tr = env.run_gsi(jax.random.PRNGKey(n), n=n, beta=beta, u=u,
                     trials=trials)
    emp = env.histogram(tr.outcomes_tilde)
    kl = float(theory.kl_mc_estimate(tilted, emp * trials))
    bound = float(theory.theorem1_kl_bound(n, float(env.chi2), beta,
                                           float(env.r.max())))
    gap = float(env.expected_golden(tilted)
                - jnp.sum(env.histogram(tr.outcomes) * env.r_star))
    print(f"{n:5d} {kl:18.5f} {bound:12.4f} "
          f"{float(tr.accept.mean()) * 100:7.1f}% {gap:+10.4f}")

print("\nKL under the bound and shrinking ~1/n -> Theorem 1 validated.")
