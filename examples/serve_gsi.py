"""End-to-end serving driver (the paper's kind of system).

Trains the draft/target/PRM triple on the synthetic reasoning task, then
serves a queue of requests with GSI through the continuous-batching
scheduler and prints per-request reasoning traces with tilted rewards (the
paper's Figure 3 style), plus accuracy/acceptance against the baselines.

    PYTHONPATH=src python examples/serve_gsi.py [--requests 8] [--n 4]
"""
import argparse

import jax
import numpy as np

from repro.config import GSIConfig
from repro.data import EOS, SEP, SyntheticReasoningTask
from repro.data.synthetic import D0, tokens_to_int
from repro.launch.serve import evaluate_queued, toy_triple, train_triple
from repro.serving import GSIScheduler, GSIServingEngine, ReplicaRouter
from repro.serving.router import POLICIES


def fmt(tokens):
    out = []
    for t in tokens:
        if t == SEP:
            out.append("\\n\\n")
        elif t == EOS:
            out.append("<eos>")
        elif t == 3:
            out.append("+")
        elif t == 4:
            out.append("=")
        elif D0 <= t < D0 + 10:
            out.append(str(t - D0))
        else:
            out.append("?")
    return "".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--n", type=int, default=4)
    ap.add_argument("--train-steps", type=int, default=600)
    ap.add_argument("--replicas", type=int, default=2,
                    help="data-parallel replicas for the router demo")
    ap.add_argument("--router", default="affinity", choices=list(POLICIES),
                    help="placement policy for the router demo")
    ap.add_argument("--sync", action="store_true",
                    help="lock-step fleet demo instead of the async "
                         "thread-per-replica loop (identical tokens)")
    args = ap.parse_args()

    task = SyntheticReasoningTask(seed=0, min_terms=2, max_terms=3,
                                  max_value=9)
    d, t, p = toy_triple()
    print("training draft / target / PRM ...", flush=True)
    ps, pb, pp = train_triple(task, d, t, p,
                              steps_draft=args.train_steps // 2,
                              steps_target=args.train_steps,
                              batch=32, seq=56)

    problems = [task.sample_problem() for _ in range(args.requests)]
    g = GSIConfig(n=args.n, beta=8.0, threshold_u=0.4, max_step_tokens=8,
                  max_steps=6, min_step_reward=0.0)
    capacity = max(1, args.requests // 2)   # offered load 2x capacity
    for mode in ["gsi", "rsd", "sbon_s", "sbon_b"]:
        eng = GSIServingEngine(d, t, p, ps, pb, pp, g, mode=mode,
                               max_seq=112)
        res = evaluate_queued(eng, task, problems, jax.random.PRNGKey(1),
                              capacity=capacity)
        print(f"{mode:8s} accuracy={res['accuracy']:.3f} "
              f"accept={res['accept_rate']:.2f} wall={res['wall_s']:.1f}s "
              f"tokens/s={res['tokens_per_s']:.1f} "
              f"p95={res['latency_p95']*1e3:.0f}ms")

    print("\n--- sample GSI reasoning traces (Fig. 3 style) ---")
    eng = GSIServingEngine(d, t, p, ps, pb, pp, g, max_seq=112)
    sched = GSIScheduler(eng, capacity=capacity)
    ids = [sched.submit(np.array(pr.prompt, np.int32))
           for pr in problems]
    results = sched.run(jax.random.PRNGKey(2))
    for i in range(min(3, args.requests)):
        pr, resp = problems[i], results[ids[i]]
        print(f"\nprompt: {fmt(pr.prompt)}   (true total {pr.total})  "
              f"[{resp.finish_reason}, {resp.engine_steps} steps]")
        for j, s in enumerate(resp.steps):
            print(f"  step {j}: {fmt(s)}")
        print(f"  correct: {task.is_correct(pr, list(resp.tokens))}")

    # every request shares the same "system prompt": after the first
    # admission batch the radix prefix cache serves the preamble's full
    # KV pages to all three models, skipping their prefill entirely
    print("\n--- prefix caching: common system preamble ---")
    pre = np.asarray([D0 + (i % 10) for i in range(33)], np.int32)
    eng_px = GSIServingEngine(d, t, p, ps, pb, pp, g, max_seq=112,
                              paged=True, page_size=16)
    sched = GSIScheduler(eng_px, capacity=capacity)
    for pr in problems:
        sched.submit(np.concatenate([pre, np.array(pr.prompt, np.int32)]))
    sched.run(jax.random.PRNGKey(3))
    st = sched.prefix_stats()
    print(f"requests={args.requests} capacity={capacity} "
          f"page_size={eng_px.page_size}")
    print(f"prefix hit_rate={st['hit_rate']:.2f} "
          f"({st['hits']}/{st['queries']} admissions) "
          f"pages_reused={st['pages_reused']} "
          f"prefill_tokens_skipped={st['hit_tokens']} "
          f"prefill_tokens={st['prefill_tokens']} "
          f"pages_evicted={st['pages_evicted']} "
          f"pages_cached={st['pages_cached']}")

    # scale out: N independent replicas behind the preamble-affinity
    # router, served asynchronously — each replica runs on its own
    # thread with one decode step in flight (sync=False), so host-side
    # admission/harvest work hides under device decode.  Two tenant
    # "system prompts"; affinity keeps each tenant's requests on the
    # replica that already caches its preamble pages, so per-replica
    # hit-rates stay as high as a single replica's.
    if args.replicas > 1:
        print(f"\n--- multi-replica routing: {args.replicas} replicas, "
              f"{args.router} policy, async fleet loop ---")
        pre_b = np.asarray([D0 + ((i + 5) % 10) for i in range(33)],
                           np.int32)
        engines = [GSIServingEngine(d, t, p, ps, pb, pp, g, max_seq=112,
                                    paged=True, page_size=16)
                   for _ in range(args.replicas)]
        router = ReplicaRouter(engines,
                               capacity=max(1, capacity // args.replicas),
                               policy=args.router,
                               sync=args.sync, threaded=not args.sync)
        for i, pr in enumerate(problems):
            preamble = pre if i < len(problems) // 2 else pre_b
            router.submit(np.concatenate([preamble,
                                          np.array(pr.prompt, np.int32)]))
        router.run(jax.random.PRNGKey(4))
        agg = router.prefix_stats()
        pipe = router.pipeline_stats()
        print(f"aggregate hit_rate={agg['hit_rate']:.2f} "
              f"({agg['hits']}/{agg['queries']} admissions) "
              f"prefill_tokens={agg['prefill_tokens']} "
              f"routing={router.routing}")
        if not args.sync:
            print(f"pipeline overlap_fraction="
                  f"{pipe['overlap_fraction']:.2f} "
                  f"(overlap {pipe['overlap_host_s']*1e3:.0f}ms / serial "
                  f"{pipe['serial_host_s']*1e3:.0f}ms host bookkeeping)")
        for rep, pstat in zip(router.replicas, agg["per_replica"]):
            print(f"  replica {rep.index}: routed={rep.routed} "
                  f"hit_rate={pstat['hit_rate']:.2f} "
                  f"({pstat['hits']}/{pstat['queries']}) "
                  f"engine_steps={rep.scheduler.engine_steps}")


if __name__ == "__main__":
    main()
