from repro.rewards.prm import PRM, OracleRewardModel  # noqa: F401
