"""Process reward models.

``PRM`` wraps a transformer with a scalar sigmoid head (rewards in [0,1],
like Qwen2.5-Math-PRM-7B in the paper).  ``OracleRewardModel`` exposes the
synthetic task's golden reward r* with the same interface — used to measure
reward hacking / Theorem 2's golden-reward convergence.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models import build_model


class PRM:
    """r(x, y): reward of a (prompt, partial-response) pair."""

    def __init__(self, cfg: ModelConfig, params=None):
        assert cfg.reward_head
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = params

    def init(self, rng):
        self.params = self.model.init(rng)
        return self.params

    def reward_sequences(self, tokens, *, source=None):
        """(B,S) tokens -> (B,S) per-position process rewards."""
        return self.model.reward(self.params, tokens, source=source)

    def reward_at_end(self, tokens, lengths, *, source=None):
        """Reward at the last real token of each sequence -> (B,)."""
        r = self.reward_sequences(tokens, source=source)
        idx = jnp.maximum(lengths - 1, 0)
        return jnp.take_along_axis(r, idx[:, None], axis=1)[:, 0]


class OracleRewardModel:
    """Golden reward r* for the synthetic reasoning task (host-side)."""

    def __init__(self, task):
        self.task = task

    def reward(self, prob, step_tokens_so_far) -> float:
        return self.task.golden_reward(prob, step_tokens_so_far)

    def batch_reward(self, probs, steps_batch) -> np.ndarray:
        return np.array([self.reward(p, s)
                         for p, s in zip(probs, steps_batch)], np.float32)
