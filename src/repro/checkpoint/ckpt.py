"""Msgpack-based pytree checkpointing (atomic write + dtype/shape fidelity)."""
from __future__ import annotations

import os
import tempfile

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _encode(tree):
    leaves, treedef = jax.tree.flatten(tree)
    payload = {
        "leaves": [
            {"dtype": str(np.asarray(l).dtype),
             "shape": list(np.asarray(l).shape),
             "data": np.ascontiguousarray(
                 np.asarray(l).view(np.uint8)
                 if np.asarray(l).dtype == jnp.bfloat16 else np.asarray(l)
             ).tobytes()}
            for l in leaves
        ],
        "treedef": str(treedef),
    }
    return payload, treedef


def save_checkpoint(path: str, tree) -> None:
    payload, _ = _encode(tree)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)))
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(msgpack.packb(payload, use_bin_type=True))
        os.replace(tmp, path)
    except BaseException:
        os.unlink(tmp)
        raise


def load_checkpoint(path: str, like_tree):
    """Restore into the structure of ``like_tree`` (shape/dtype checked)."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    leaves, treedef = jax.tree.flatten(like_tree)
    if len(leaves) != len(payload["leaves"]):
        raise ValueError(
            f"checkpoint has {len(payload['leaves'])} leaves, "
            f"expected {len(leaves)}")
    out = []
    for ref, rec in zip(leaves, payload["leaves"]):
        dtype = rec["dtype"]
        shape = tuple(rec["shape"])
        if dtype == "bfloat16":
            arr = np.frombuffer(rec["data"], np.uint8).view(jnp.bfloat16)
        else:
            arr = np.frombuffer(rec["data"], np.dtype(dtype))
        arr = arr.reshape(shape)
        if shape != tuple(np.asarray(ref).shape):
            raise ValueError(f"shape mismatch {shape} vs "
                             f"{np.asarray(ref).shape}")
        out.append(jnp.asarray(arr))
    return jax.tree.unflatten(treedef, out)
