"""Render the EXPERIMENTS.md §Dry-run / §Roofline tables from dryrun.json.

    PYTHONPATH=src python -m repro.roofline.report results/dryrun.json
"""
from __future__ import annotations

import json
import sys


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.2f}ms"
    return f"{x * 1e6:.1f}us"


def render(path: str, mesh: str = "single") -> str:
    data = json.load(open(path))
    rows = []
    for key, v in sorted(data.items()):
        arch, shape, mk = key.split("|")
        if mk != mesh:
            continue
        if v.get("status") != "ok":
            rows.append(f"| {arch} | {shape} | FAILED: "
                        f"{v.get('error', '?')[:60]} | | | | | |")
            continue
        dom = v["dominant"]
        rows.append(
            "| {arch} | {shape} | {c} | {m} | {coll} | **{dom}** | "
            "{ratio:.2f} | {peak:.1f} |".format(
                arch=arch, shape=shape,
                c=fmt_s(v["compute_s"]), m=fmt_s(v["memory_s"]),
                coll=fmt_s(v["collective_s"]), dom=dom,
                ratio=v.get("useful_flops_ratio", 0.0),
                peak=v.get("peak_bytes", 0) / 2 ** 30))
    header = (
        f"**mesh: {mesh}** (terms are per-device seconds; v5e constants)\n\n"
        "| arch | shape | compute | memory | collective | dominant | "
        "useful-FLOPs | peak GiB/dev |\n"
        "|---|---|---|---|---|---|---|---|\n")
    return header + "\n".join(rows) + "\n"


LEVERS = {
    ("memory", "train"): "flash-attention kernel (removes S^2 score traffic)"
                         " / remat policy",
    ("memory", "prefill"): "flash-attention kernel; bf16 end-to-end",
    ("memory", "decode"): "KV-cache sequence-sharding over idle mesh axes "
                          "(H2 iter-2); int8 cache",
    ("collective", "train"): "drop embed-dim FSDP below ~1e11 params "
                             "(H1); overlap grad all-reduce with backward",
    ("collective", "prefill"): "reduce-scatter matmul outputs instead of "
                               "all-reduce; 2D weight layout",
    ("collective", "decode"): "token-replicated expert-parallel MoE "
                              "(H2 iter-1); avoid weight regathers",
    ("compute", "train"): "already compute-bound: MFU via larger per-core "
                          "batch / MXU-aligned dims",
    ("compute", "prefill"): "already compute-bound (healthy)",
    ("compute", "decode"): "batch more requests per step",
}


def notes(path: str, mesh: str = "single") -> str:
    from repro.config import SHAPES
    data = json.load(open(path))
    out = []
    for key, v in sorted(data.items()):
        arch, shape, mk = key.split("|")
        if mk != mesh or v.get("status") != "ok":
            continue
        kind = SHAPES[shape].kind
        lever = LEVERS.get((v["dominant"], kind), "")
        acc = " [ssm two-point accounting]" \
            if v.get("accounting") else ""
        out.append(
            f"* **{arch} / {shape}** — dominant **{v['dominant']}** "
            f"({fmt_s(max(v['compute_s'], v['memory_s'], v['collective_s']))}"
            f"); useful-FLOPs {v.get('useful_flops_ratio', 0):.2f}{acc}. "
            f"Lever: {lever}.")
    return "\n".join(out) + "\n"


def fill_experiments(path="results/dryrun.json",
                     md_path="EXPERIMENTS.md") -> None:
    md = open(md_path).read()
    table = render(path, "single") + "\n" + render(path, "multi")
    md = md.replace("<!-- DRYRUN_TABLE -->", table, 1)
    md = md.replace("<!-- ROOFLINE_TABLE -->",
                    "### Per-pair bottleneck notes (single-pod)\n\n"
                    + notes(path, "single"), 1)
    open(md_path, "w").write(md)
    print(f"wrote tables into {md_path}")


def main():
    if len(sys.argv) > 1 and sys.argv[1] == "--fill":
        fill_experiments(*(sys.argv[2:] or []))
        return
    path = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun.json"
    for mesh in ("single", "multi"):
        print(render(path, mesh))


if __name__ == "__main__":
    main()
