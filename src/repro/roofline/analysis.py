"""Roofline analysis from compiled dry-run artifacts (EXPERIMENTS §Roofline).

Three terms per (arch x shape x mesh), all in seconds:

    compute    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory     = HLO_bytes / (chips * HBM_bw)
    collective = collective_bytes / (chips * link_bw)

FLOPs/bytes come from ``compiled.cost_analysis()``.  collective_bytes is
parsed out of the optimized HLO: we sum the result-shape bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute,
attributing ops inside while-loop bodies their known trip count (XLA
annotates ``known_trip_count`` on scan-derived loops — our layer scans).

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict, List

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-_]+)\s*\([^)]*\)\s*->")
_CALLEE_RE = re.compile(r"(?:to_apply|body|condition|branch_computations)="
                        r"[{]?%?([\w\.\-_, %]+)[}]?")
_TRIP_RE = re.compile(r'known_trip_count[^\d]*(\d+)')


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            n = math.prod(int(d) for d in dims.split(","))
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class HLOCollectives:
    per_comp_bytes: Dict[str, float] = field(default_factory=dict)
    per_comp_ops: Dict[str, List[str]] = field(default_factory=dict)
    calls: Dict[str, list] = field(default_factory=dict)  # comp -> [(callee, mult)]
    entry: str = ""

    def total_bytes(self, comp=None, _seen=None) -> float:
        comp = comp or self.entry
        _seen = _seen or set()
        if comp in _seen or comp not in self.per_comp_bytes and \
                comp not in self.calls:
            pass
        total = self.per_comp_bytes.get(comp, 0.0)
        for callee, mult in self.calls.get(comp, []):
            if callee == comp:
                continue
            total += mult * self.total_bytes(callee, _seen | {comp})
        return total


def collective_bytes(hlo_text: str) -> HLOCollectives:
    """Parse optimized HLO; returns per-computation collective byte counts."""
    res = HLOCollectives()
    cur = None
    pending_trip: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = _COMP_RE.match(line)  # computation headers start at col 0
        if m and (line.startswith("%") or line.startswith("ENTRY")):
            cur = m.group(1)
            if line.startswith("ENTRY"):
                res.entry = cur
            continue
        if cur is None:
            continue
        # collective ops (start variants also: "all-gather-start")
        op = None
        for c in _COLLECTIVES:
            if re.search(rf"=\s.*\b{c}(-start)?\(", stripped):
                op = c
                break
        if op:
            lhs = stripped.split("=", 1)
            type_part = lhs[1] if len(lhs) > 1 else stripped
            type_part = type_part.split(op)[0]
            b = _shape_bytes(type_part)
            res.per_comp_bytes[cur] = res.per_comp_bytes.get(cur, 0.0) + b
            res.per_comp_ops.setdefault(cur, []).append(
                f"{op}:{b/1e6:.1f}MB")
        # calls / control flow
        cm = _CALLEE_RE.search(stripped)
        if cm:
            mult = 1
            tm = _TRIP_RE.search(stripped)
            if tm:
                mult = int(tm.group(1))
            elif " while(" in stripped or stripped.startswith("while("):
                mult = 1  # unknown trip count -> counted once (flagged)
            for callee in re.split(r"[,\s]+", cm.group(1)):
                callee = callee.strip().lstrip("%")
                if callee:
                    res.calls.setdefault(cur, []).append((callee, mult))
    return res


@dataclass
class RooflineReport:
    """All inputs are PER-DEVICE quantities.

    ``compiled.cost_analysis()`` on an SPMD program reports the per-device
    share of FLOPs/bytes (verified empirically: an 8-way-sharded matmul
    reports 1/8 of the global FLOPs), and the parsed HLO collective bytes
    are the per-device program's transfer sizes.  ``model_flops`` should
    therefore be passed as global_model_flops / chips.
    """
    name: str
    chips: int
    flops: float
    bytes_accessed: float
    coll_bytes: float
    model_flops: float = 0.0

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_accessed / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0

    def as_dict(self) -> dict:
        return {
            "name": self.name, "chips": self.chips,
            "hlo_flops": self.flops, "hlo_bytes": self.bytes_accessed,
            "collective_bytes": self.coll_bytes,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
        }


def roofline_terms(name: str, compiled, *, chips: int,
                   model_flops: float = 0.0,
                   hlo_text: str = None) -> RooflineReport:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = collective_bytes(text)
    return RooflineReport(name, chips, flops, byts, coll.total_bytes(),
                          model_flops)


def model_flops_estimate(cfg, tokens: int, kind: str) -> float:
    """MODEL_FLOPS = 6*N_active*D for train, 2*N_active*D for inference."""
    n_active = cfg.active_param_count()
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_active * tokens
