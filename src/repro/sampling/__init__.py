from repro.sampling.sampler import (  # noqa: F401
    sample_token, sample_steps, score_and_append, StepBatch)
