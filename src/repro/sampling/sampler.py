"""Token sampling + reasoning-step generation / scoring.

A *reasoning step* ends at the sep token ("\\n\\n" in the paper) or EOS.
``sample_steps`` autoregressively samples one step per request (scratch
cache — the engine discards it); ``score_and_append`` teacher-forces given
step tokens through a model, returning their total log-probability and the
cache extended by exactly those tokens (scoring and cache-append are the
same pass — DESIGN.md §3).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

PAD = 0


class StepBatch(NamedTuple):
    tokens: jnp.ndarray      # (B, L) sampled step tokens (PAD after end)
    length: jnp.ndarray      # (B,) tokens in the step (incl. sep/eos)
    logprob: jnp.ndarray     # (B,) sum log pi(token) over the step
    ended: jnp.ndarray       # (B,) step terminated naturally (sep or eos)
    eos: jnp.ndarray         # (B,) step terminated with EOS
    cache: object            # scratch cache after the step (usually discarded)
    positions: jnp.ndarray   # (B,) position after the step


def top_p_filter(logits, top_p: float):
    """Nucleus filtering: mask tokens outside the smallest top-p set.

    Implemented via a cutoff value (keep every token whose logit >= the
    boundary token's logit) so ties at the boundary are all kept — this
    keeps the filter deterministic and always retains the argmax.
    """
    if top_p >= 1.0:
        return logits
    sort = jnp.sort(logits, axis=-1)[..., ::-1]
    probs = jax.nn.softmax(sort, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    # keep tokens while cumulative prob (exclusive) < top_p
    keep_sorted = cum - probs < top_p
    cutoff = jnp.min(jnp.where(keep_sorted, sort, jnp.inf), axis=-1,
                     keepdims=True)
    return jnp.where(logits >= cutoff, logits, -1e30)


def sample_token(rng, logits, temperature: float = 1.0, top_p: float = 1.0):
    """logits: (B,V) -> tokens (B,). Greedy when temperature == 0."""
    if temperature == 0.0:
        return jnp.argmax(logits, axis=-1)
    scaled = logits.astype(jnp.float32) / max(temperature, 1e-6)
    scaled = top_p_filter(scaled, top_p)
    return jax.random.categorical(rng, scaled, axis=-1)


def sample_steps(model, params, cache, last_token, positions, rng, *,
                 max_tokens: int, sep_token: int, eos_token: int,
                 temperature: float = 0.7, top_p: float = 1.0,
                 already_done=None, pt=None) -> StepBatch:
    """Sample one reasoning step per request.

    last_token/positions: (B,) — the last committed token and its position.
    Returns the sampled step and the scratch cache positioned after it.
    The returned ``logprob`` is the *model* log-likelihood of the sampled
    tokens (temperature affects sampling only), matching the paper's use of
    raw log-probabilities in the tilted reward.
    """
    B = last_token.shape[0]
    done0 = jnp.zeros((B,), bool) if already_done is None else already_done

    def body(carry, rng_t):
        cache, tok, pos, done, lp = carry
        logits, cache = model.decode_step(params, cache, tok[:, None], pos,
                                          live=~done, pt=pt)
        nxt = sample_token(rng_t, logits, temperature, top_p)
        logp_all = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        logp_tok = jnp.take_along_axis(logp_all, nxt[:, None], axis=-1)[:, 0]
        nxt = jnp.where(done, PAD, nxt)
        lp = lp + jnp.where(done, 0.0, logp_tok)
        ended_now = (nxt == sep_token) | (nxt == eos_token)
        new_done = done | ended_now
        new_pos = jnp.where(done, pos, pos + 1)
        return (cache, nxt, new_pos, new_done, lp), (nxt, new_done)

    rngs = jax.random.split(rng, max_tokens)
    (cache, _, pos, done, lp), (toks, dones) = jax.lax.scan(
        body, (cache, last_token, positions, done0,
               jnp.zeros((B,), jnp.float32)), rngs)
    toks = jnp.moveaxis(toks, 0, 1)        # (B, L)
    dones = jnp.moveaxis(dones, 0, 1)
    length = jnp.sum(toks != PAD, axis=1)
    ended = done
    eos = jnp.any(toks == eos_token, axis=1)
    return StepBatch(toks, length, lp, ended, eos, cache, pos)


def score_and_append(model, params, cache, last_token, positions,
                     step_tokens, *, return_rewards: bool = False,
                     row_live=None, pt=None):
    """Teacher-force ``step_tokens`` (B,L; PAD-padded) through the model.

    Returns (logprob (B,), new_cache, new_positions[, rewards (B,)]).
    ``rewards`` (PRM models) is the reward head evaluated at the *last* real
    token of each step.  The cache is advanced by exactly the real tokens.

    ``row_live`` (B,) bool freezes whole requests regardless of their token
    content — the prefill-into-slot path of the continuous-batching
    scheduler commits prompt tails for newly admitted slots while requests
    occupying the other slots pass through untouched.
    """
    B, L = step_tokens.shape

    def body(carry, xs):
        cache, tok, pos, lp, rw, fed_live = carry
        target = xs                                     # (B,) token to score
        live = target != PAD
        if row_live is not None:
            live = live & row_live
        out = model.decode_step(params, cache, tok[:, None], pos, live=live,
                                return_hidden=return_rewards, pt=pt)
        if return_rewards:
            logits, cache, hidden = out
            # reward head evaluated on the token *fed* this iteration;
            # fed_live marks whether it was a real (non-frozen) step token.
            r_here = model.reward_from_hidden(params, hidden)
            rw = jnp.where(fed_live, r_here, rw)
        else:
            logits, cache = out
        logp_all = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        lp_tok = jnp.take_along_axis(
            logp_all, jnp.maximum(target, 0)[:, None], axis=-1)[:, 0]
        lp = lp + jnp.where(live, lp_tok, 0.0)
        pos = jnp.where(live, pos + 1, pos)
        tok = jnp.where(live, target, tok)
        return (cache, tok, pos, lp, rw, live), None

    zeros = jnp.zeros((B,), jnp.float32)
    # one extra PAD iteration so the reward of the final token is captured
    xs = jnp.concatenate([step_tokens, jnp.zeros((B, 1), step_tokens.dtype)],
                         axis=1)
    (cache, _, pos, lp, rw, _), _ = jax.lax.scan(
        body, (cache, last_token, positions, zeros, zeros,
               jnp.ones((B,), bool)),
        jnp.moveaxis(xs, 0, 1))
    if return_rewards:
        return lp, cache, pos, rw
    return lp, cache, pos
