"""Shared model machinery: ParamSpec trees, init, norms, RoPE, FFN.

Single source of truth for parameters: every module builds a pytree of
:class:`ParamSpec` (shape + logical axis names + initializer).  The same tree
is used to (a) materialize parameters and (b) derive PartitionSpecs via the
logical-axis rules in ``repro.distributed.sharding``.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import tp as _tp

# ---------------------------------------------------------------------------
# ParamSpec machinery
# ---------------------------------------------------------------------------


class ParamSpec(NamedTuple):
    shape: tuple
    axes: tuple          # logical axis name (or None) per dim
    init: str = "normal"  # normal | zeros | ones | embed | uniform_decay
    scale: float = 1.0    # stddev multiplier for "normal"


def spec(shape, axes, init="normal", scale=1.0) -> ParamSpec:
    assert len(shape) == len(axes), (shape, axes)
    return ParamSpec(tuple(int(s) for s in shape), tuple(axes), init, scale)


def is_param_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def tree_map_specs(fn, tree):
    return jax.tree.map(fn, tree, is_leaf=is_param_spec)


def stack_specs(tree, n: int):
    """Add a leading 'layer' (scan) dimension to every spec in the tree."""
    return tree_map_specs(
        lambda s: ParamSpec((n,) + s.shape, ("layer",) + s.axes, s.init, s.scale),
        tree)


def init_params(spec_tree, rng, param_dtype):
    """Materialize a spec tree into arrays (deterministic per-leaf folding)."""
    leaves, treedef = jax.tree.flatten(spec_tree, is_leaf=is_param_spec)
    arrays = []
    for i, s in enumerate(leaves):
        key = jax.random.fold_in(rng, i)
        arrays.append(_materialize(s, key, param_dtype))
    return jax.tree.unflatten(treedef, arrays)


def _materialize(s: ParamSpec, key, dtype):
    if s.init == "zeros":
        return jnp.zeros(s.shape, dtype)
    if s.init == "ones":
        return jnp.ones(s.shape, dtype)
    if s.init == "uniform_decay":
        # RG-LRU lambda parametrization: a = sigmoid(L) in [0.9, 0.999]
        u = jax.random.uniform(key, s.shape, jnp.float32, 0.9, 0.999)
        lam = jnp.log(u) - jnp.log1p(-u)
        return lam.astype(dtype)
    # fan-in scaled normal; embeddings scale by 1.0
    if s.init == "embed":
        std = 1.0
    else:
        fan_in = s.shape[-2] if len(s.shape) >= 2 else s.shape[-1]
        # stacked specs carry a leading layer dim -> fan-in is dim -2 of the
        # trailing matrix; for 3D projection tensors (d, H, hd) fan-in = d.
        if len(s.shape) >= 3:
            fan_in = s.shape[-3] if s.axes[-1] == "head" else s.shape[-2]
        std = 1.0 / math.sqrt(max(1, fan_in))
    arr = jax.random.normal(key, s.shape, jnp.float32) * (std * s.scale)
    return arr.astype(dtype)


# ---------------------------------------------------------------------------
# Numerics helpers
# ---------------------------------------------------------------------------

def adtype(cfg):
    return jnp.dtype(cfg.dtype)


def rms_norm(x, gamma, eps):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + gamma.astype(jnp.float32))).astype(x.dtype)


def norm_spec(d):
    return spec((d,), ("embed",), "zeros")  # "1+gamma" parametrization


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    exponents = np.arange(0, head_dim, 2, dtype=np.float32) / head_dim
    return 1.0 / (theta ** exponents)  # (head_dim/2,)


def apply_rope(x, positions, theta):
    """x: (..., S, H, hd); positions broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta))
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    angles = angles[..., None, :]  # head axis
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Gated FFN (SwiGLU) — the dense MLP used by every non-ssm family
# ---------------------------------------------------------------------------

def ffn_specs(d, ff):
    return {
        "wi_gate": spec((d, ff), ("embed", "mlp")),
        "wi_up": spec((d, ff), ("embed", "mlp")),
        "wo": spec((ff, d), ("mlp", "embed")),
    }


def ffn_apply(p, x, *, d_ff=None):
    """SwiGLU FFN; ``d_ff`` (the config's global width) enables the
    tensor-parallel hook: when the held weights are narrower than
    ``d_ff`` inside a :func:`repro.distributed.tp.tensor_parallel`
    trace, the gate/up matmuls run column-sharded (exact — they
    contract over the replicated d_model dim) and the down-projection
    all-gathers both the activation and ``wo`` before one full matmul,
    which is bitwise-identical to the unsharded computation (a
    psum-of-partials would reorder float additions and is not)."""
    wo = p["wo"]
    gate = jax.nn.silu(x @ p["wi_gate"])
    h = gate * (x @ p["wi_up"])
    ax = _tp.axis()
    if ax is not None and d_ff is not None and wo.shape[0] != d_ff:
        h = jax.lax.all_gather(h, ax, axis=h.ndim - 1, tiled=True)
        wo = jax.lax.all_gather(wo, ax, axis=0, tiled=True)
    return h @ wo


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def padded_vocab(cfg) -> int:
    """Vocab padded to a multiple of 512 for clean TP sharding + MXU tiles."""
    return round_up(cfg.vocab_size, 512)


def embed_specs(cfg):
    v = padded_vocab(cfg)
    s = {"embedding": spec((v, cfg.d_model), ("vocab", "embed"), "embed")}
    if not cfg.tie_embeddings:
        s["unembed"] = spec((cfg.d_model, v), ("embed", "vocab"))
    return s


def embed_tokens(cfg, p, tokens):
    """Token embedding lookup; vocab-sharded under a tp trace.

    When the held embedding has fewer rows than ``padded_vocab(cfg)``
    inside a tensor-parallel trace, each device gathers the rows whose
    ids fall in its vocab shard (others zeroed) and a ``psum`` merges
    them — exactly one shard contributes per token and x + 0 == x in
    floating point, so the result is bitwise-identical to unsharded.
    """
    emb = p["embedding"]
    ax = _tp.axis()
    if ax is not None and emb.shape[0] != padded_vocab(cfg):
        v_local = emb.shape[0]
        local = tokens - jax.lax.axis_index(ax) * v_local
        valid = (local >= 0) & (local < v_local)
        rows = emb.astype(adtype(cfg))[jnp.clip(local, 0, v_local - 1)]
        return jax.lax.psum(jnp.where(valid[..., None], rows, 0), ax)
    return emb.astype(adtype(cfg))[tokens]


def unembed(cfg, p, x):
    """Project hidden states to (masked) vocab logits.

    Under a tensor-parallel trace with a vocab-sharded unembedding,
    each device computes its exact logit columns (the contraction runs
    over the replicated d_model dim) and an ``all_gather`` over the
    vocab dim reassembles the full row — bitwise-identical to the
    unsharded matmul.  The padded-vocab mask applies globally after.
    """
    w = p["unembed"] if "unembed" in p else p["embedding"].T
    logits = (x @ w.astype(x.dtype)).astype(jnp.dtype(cfg.logit_dtype))
    v = padded_vocab(cfg)
    ax = _tp.axis()
    if ax is not None and w.shape[-1] != v:
        logits = jax.lax.all_gather(logits, ax, axis=logits.ndim - 1,
                                    tiled=True)
    if v != cfg.vocab_size:
        # mask padding rows so they never win a softmax
        mask = jnp.arange(v) < cfg.vocab_size
        logits = jnp.where(mask, logits, -1e30)
    return logits
