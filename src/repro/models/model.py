"""The unified Model API over all architecture families.

``Model`` wraps a :class:`repro.config.ModelConfig` and exposes:

  * ``param_specs()`` / ``init(rng)``    — parameter tree (spec / arrays)
  * ``forward(params, tokens, source)``  — full-sequence logits (training)
  * ``prefill(params, tokens, ...)``     — last-token logits + KV cache
  * ``decode_step(params, cache, tok, pos)`` — one-token serving step
  * ``init_cache(batch, max_seq)``       — zeroed cache pytree
  * ``score(params, tokens, ...)``       — log p(tokens) per position (GSI)
  * ``reward(params, tokens, ...)``      — PRM head scores per position

Layers are grouped into *pattern blocks* and scanned with ``jax.lax.scan``
(HLO size O(|pattern|), see DESIGN.md §5); the remainder layers (pattern
prefix) are applied unscanned at the end of the stack.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import blocks as B
from repro.models.common import (adtype, embed_specs, embed_tokens,
                                 init_params, norm_spec, rms_norm, spec,
                                 stack_specs, unembed)


def effective_pattern(cfg: ModelConfig):
    if cfg.family == "ssm":
        # pattern length controls the scan-body size (all blocks are rwkv);
        # the dry-run uses a 2-long body for its two-point cost accounting.
        return ("rwkv",) * len(cfg.layer_pattern)
    return tuple(cfg.layer_pattern)


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.pattern = effective_pattern(cfg)
        n = len(self.pattern)
        self.repeats = cfg.num_layers // n if cfg.scan_layers else 0
        rem = cfg.num_layers - self.repeats * n
        self.remainder = self.pattern[:rem] if cfg.scan_layers else \
            tuple(self.pattern * ((cfg.num_layers + n - 1) // n))[:cfg.num_layers]

    # ------------------------------------------------------------------
    # Parameters
    # ------------------------------------------------------------------
    def param_specs(self):
        cfg = self.cfg
        specs = {"embed": embed_specs(cfg), "final_ln": norm_spec(cfg.d_model)}
        if self.repeats:
            specs["blocks"] = {
                f"p{i}": stack_specs(B.block_specs(cfg, kind), self.repeats)
                for i, kind in enumerate(self.pattern)}
        if self.remainder:
            specs["rem"] = {
                f"r{i}": B.block_specs(cfg, kind)
                for i, kind in enumerate(self.remainder)}
        if cfg.encoder_layers:
            specs["encoder"] = {
                "blocks": stack_specs(B.block_specs(cfg, "enc"),
                                      cfg.encoder_layers),
                "final_ln": norm_spec(cfg.d_model),
            }
        if cfg.reward_head:
            specs["reward_head"] = {
                "w": spec((cfg.d_model, 1), ("embed", None)),
                "b": spec((1,), (None,), "zeros"),
            }
        return specs

    def init(self, rng):
        return init_params(self.param_specs(), rng, jnp.dtype(
            self.cfg.param_dtype))

    # ------------------------------------------------------------------
    # Encoder (audio family): frames (B, enc_seq, d) -> source embeddings
    # ------------------------------------------------------------------
    def encode(self, params, frames):
        cfg = self.cfg
        x = frames.astype(adtype(cfg))
        positions = jnp.arange(x.shape[1])

        def body(carry, bp):
            y, _, _ = B.block_apply(cfg, "enc", bp, carry, mode="train",
                                    positions=positions)
            return y, None

        x, _ = jax.lax.scan(body, x, params["encoder"]["blocks"])
        return rms_norm(x, params["encoder"]["final_ln"], cfg.norm_eps)

    # ------------------------------------------------------------------
    # Core stack
    # ------------------------------------------------------------------
    def _run_stack(self, params, x, *, mode, positions, cache=None,
                   source=None, max_seq=0, window_override=0, live=None,
                   pt=None):
        cfg = self.cfg
        aux_total = 0.0
        new_cache = {"blocks": None, "rem": None}

        apply = functools.partial(
            B.block_apply, cfg, mode=mode, positions=positions,
            source=source, max_seq=max_seq, window_override=window_override,
            live=live, pt=pt)

        if self.repeats:
            def body(carry, xs):
                h = carry
                bp, csl = xs
                out_slices, aux = {}, 0.0
                for i, kind in enumerate(self.pattern):
                    key = f"p{i}"
                    c = None if csl is None else csl[key]
                    h, nc, a = apply(kind, bp[key], h, cache=c)
                    out_slices[key] = nc
                    aux = aux + a
                return h, (out_slices, aux)

            cache_xs = None if cache is None else cache["blocks"]
            x, (stacked_cache, auxs) = jax.lax.scan(
                body, x, (params["blocks"], cache_xs))
            new_cache["blocks"] = stacked_cache
            aux_total = aux_total + jnp.sum(auxs) if self._has_aux() else 0.0

        if self.remainder:
            rem_cache = {}
            for i, kind in enumerate(self.remainder):
                key = f"r{i}"
                c = None if cache is None else cache["rem"][key]
                x, nc, a = apply(kind, params["rem"][key], x, cache=c)
                rem_cache[key] = nc
                aux_total = aux_total + a
            new_cache["rem"] = rem_cache

        x = rms_norm(x, params["final_ln"], cfg.norm_eps)
        return x, new_cache, aux_total

    def _has_aux(self):
        return bool(self.cfg.num_experts)

    # ------------------------------------------------------------------
    # Public entry points
    # ------------------------------------------------------------------
    def forward(self, params, tokens, *, source=None):
        """Training forward: (B,S) tokens -> (logits (B,S,V), aux_loss)."""
        cfg = self.cfg
        if cfg.encoder_layers:
            source = self.encode(params, source)
        x = embed_tokens(cfg, params["embed"], tokens)
        positions = jnp.arange(tokens.shape[1])
        x, _, aux = self._run_stack(params, x, mode="train",
                                    positions=positions, source=source)
        return unembed(cfg, params["embed"], x), aux

    def hidden(self, params, tokens, *, source=None):
        """Final hidden states (B,S,d) — used by score() and reward()."""
        cfg = self.cfg
        if cfg.encoder_layers:
            source = self.encode(params, source)
        x = embed_tokens(cfg, params["embed"], tokens)
        positions = jnp.arange(tokens.shape[1])
        x, _, _ = self._run_stack(params, x, mode="train",
                                  positions=positions, source=source)
        return x

    def prefill(self, params, tokens, *, source=None, max_seq=0):
        """(B,S) tokens -> (last-token logits (B,V), cache)."""
        cfg = self.cfg
        if cfg.encoder_layers:
            source = self.encode(params, source)
        x = embed_tokens(cfg, params["embed"], tokens)
        positions = jnp.arange(tokens.shape[1])
        x, cache, _ = self._run_stack(
            params, x, mode="prefill", positions=positions, source=source,
            max_seq=max_seq or tokens.shape[1],
            window_override=cfg.serve_window_override)
        logits = unembed(cfg, params["embed"], x[:, -1:])[:, 0]
        return logits, cache

    def decode_step(self, params, cache, tokens, positions, live=None,
                    return_hidden: bool = False, pt=None):
        """One serving step: tokens (B,1), positions (B,) -> (logits, cache).

        ``live`` (B,) bool freezes recurrent state for finished requests.
        ``return_hidden`` additionally returns the final hidden state (B,d)
        (used by the PRM reward head in the serving engine).  ``pt`` (B,
        nblk) routes attention layers through the paged KV-cache path.
        """
        cfg = self.cfg
        x = embed_tokens(cfg, params["embed"], tokens)
        x, new_cache, _ = self._run_stack(
            params, x, mode="decode", positions=positions, cache=cache,
            window_override=cfg.serve_window_override, live=live, pt=pt)
        logits = unembed(cfg, params["embed"], x)[:, 0]
        if return_hidden:
            return logits, new_cache, x[:, 0]
        return logits, new_cache

    def reward_from_hidden(self, params, h):
        """PRM head on a hidden state (..., d) -> reward in [0,1]."""
        rh = params["reward_head"]
        logit = (h.astype(jnp.float32) @ rh["w"].astype(jnp.float32)
                 )[..., 0] + rh["b"].astype(jnp.float32)
        return jax.nn.sigmoid(logit)

    def init_cache(self, batch: int, max_seq: int, *, pages: int = 0,
                   page_size: int = 0, kv_dtype=None):
        """Zeroed cache pytree; ``pages > 0`` selects the paged layout
        (attention leaves become shared page pools, see serving/pages.py).
        ``kv_dtype`` selects the page-pool storage format (int8/fp8 add
        per-page scale tensors alongside the pools)."""
        cfg = self.cfg
        cache = {"blocks": None, "rem": None}
        kw = dict(pages=pages, page_size=page_size, kv_dtype=kv_dtype)
        if self.repeats:
            def stack_zero(kind):
                one = B.init_block_cache(cfg, kind, batch, max_seq, **kw)
                return jax.tree.map(
                    lambda a: jnp.broadcast_to(
                        a[None], (self.repeats,) + a.shape), one)
            cache["blocks"] = {f"p{i}": stack_zero(k)
                               for i, k in enumerate(self.pattern)}
        if self.remainder:
            cache["rem"] = {f"r{i}": B.init_block_cache(cfg, k, batch,
                                                        max_seq, **kw)
                            for i, k in enumerate(self.remainder)}
        return cache

    def score(self, params, tokens, *, source=None):
        """log pi(tokens[t] | tokens[<t]) for t>=1 -> (B, S-1).

        The GSI target-scoring pass: one parallel forward, no generation.
        Dispatches to the fused logprob-gather kernel when enabled.
        """
        h = self.hidden(params, tokens[:, :-1], source=source)
        labels = tokens[:, 1:]
        from repro.kernels import ops
        w = params["embed"].get("unembed")
        if w is None:
            w = params["embed"]["embedding"].T
        return ops.logprob_gather(h, w, labels, self.cfg.vocab_size)

    def reward(self, params, tokens, *, source=None):
        """PRM: per-position reward in [0,1] -> (B,S)."""
        assert self.cfg.reward_head, "reward() needs cfg.reward_head"
        h = self.hidden(params, tokens, source=source)
        rh = params["reward_head"]
        logit = (h.astype(jnp.float32) @ rh["w"].astype(jnp.float32)
                 )[..., 0] + rh["b"].astype(jnp.float32)
        return jax.nn.sigmoid(logit)


@functools.lru_cache(maxsize=64)
def _cached_model(cfg: ModelConfig) -> Model:
    return Model(cfg)


def build_model(cfg: ModelConfig) -> Model:
    return _cached_model(cfg)
