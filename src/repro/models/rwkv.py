"""RWKV-6 "Finch" blocks: time-mix with data-dependent decay + channel-mix.

Faithful to arXiv:2404.05892 §3 (DDLerp token shift, LoRA decay, per-head
matrix-valued state) with two documented simplifications (DESIGN.md §6):
RMSNorm instead of LayerNorm, and a shared 32-dim LoRA rank for the five
token-shift mixes.

State per layer (decode): time-mix shift x_prev (B,d), WKV state (B,H,hd,hd),
channel-mix shift (B,d).  Training/prefill uses a sequence scan (the Pallas
``rwkv6_scan`` kernel implements the chunked TPU variant; this file is the
oracle semantics).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.models.common import adtype, rms_norm, spec

LORA_RANK = 32
DECAY_RANK = 64


def timemix_specs(cfg):
    d = cfg.d_model
    H, hd = cfg.num_heads, cfg.rwkv_head_dim
    return {
        "mu_x": spec((d,), ("embed",), "zeros"),
        "mu_5": spec((5, d), (None, "embed"), "zeros"),
        "tm_w1": spec((d, 5 * LORA_RANK), ("embed", None), scale=0.1),
        "tm_w2": spec((5, LORA_RANK, d), (None, None, "embed"), scale=0.1),
        "decay_base": spec((d,), ("embed",), "uniform_decay"),
        "decay_w1": spec((d, DECAY_RANK), ("embed", None), scale=0.1),
        "decay_w2": spec((DECAY_RANK, d), (None, "embed"), scale=0.1),
        "bonus_u": spec((H, hd), ("heads", "head"), scale=0.5),
        "wr": spec((d, d), ("embed", "heads_flat")),
        "wk": spec((d, d), ("embed", "heads_flat")),
        "wv": spec((d, d), ("embed", "heads_flat")),
        "wg": spec((d, d), ("embed", "heads_flat")),
        "wo": spec((d, d), ("heads_flat", "embed")),
        "ln_x": spec((d,), ("embed",), "zeros"),
    }


def channelmix_specs(cfg):
    d, ff = cfg.d_model, cfg.d_ff
    return {
        "mu_k": spec((d,), ("embed",), "zeros"),
        "mu_r": spec((d,), ("embed",), "zeros"),
        "wk": spec((d, ff), ("embed", "mlp")),
        "wv": spec((ff, d), ("mlp", "embed")),
        "wr": spec((d, d), ("embed", "embed_out")),
    }


def init_rwkv_state(cfg, batch: int):
    d = cfg.d_model
    H, hd = cfg.num_heads, cfg.rwkv_head_dim
    f32 = jnp.float32
    return {
        "tm_prev": jnp.zeros((batch, d), adtype(cfg)),
        "wkv": jnp.zeros((batch, H, hd, hd), f32),
        "cm_prev": jnp.zeros((batch, d), adtype(cfg)),
    }


def _ddlerp(p, x, sx):
    """Data-dependent token-shift mixes for (w,k,v,r,g).

    x, sx: (B,T,d) with sx = x_prev - x.  Returns 5 tensors (B,T,d).
    """
    base = x + sx * p["mu_x"]
    lo = jnp.tanh(base @ p["tm_w1"])            # (B,T,5*R)
    B, T = x.shape[:2]
    lo = lo.reshape(B, T, 5, LORA_RANK)
    delta = jnp.einsum("btfr,frd->btfd", lo, p["tm_w2"])  # (B,T,5,d)
    mixes = p["mu_5"][None, None] + delta
    out = x[:, :, None] + sx[:, :, None] * mixes
    return [out[:, :, i] for i in range(5)]


def _decay(p, xw):
    """Data-dependent per-channel decay w_t in (0,1).  xw: (B,T,d)."""
    lora = jnp.tanh(xw @ p["decay_w1"]) @ p["decay_w2"]
    log_w = -jnp.exp(
        jnp.clip((p["decay_base"] + lora).astype(jnp.float32), -8.0, 4.0))
    return jnp.exp(log_w)  # in (0,1)


def _wkv_scan(r, k, v, w, u, state):
    """Sequential WKV recurrence (oracle semantics).

    r,k,v: (B,T,H,hd); w: (B,T,H,hd) decays; u: (H,hd); state: (B,H,hd,hd).
    out_t = r_t . (S_{t-1} + u*k_t (x) v_t);  S_t = diag(w_t) S_{t-1} + k_t (x) v_t
    """
    rf, kf, vf, wf = (a.astype(jnp.float32) for a in (r, k, v, w))

    def step(S, inp):
        rt, kt, vt, wt = inp  # (B,H,hd)
        kv = kt[..., :, None] * vt[..., None, :]          # (B,H,hd,hd)
        out = jnp.einsum("bhk,bhkn->bhn", rt,
                         S + u[None, :, :, None] * kv)
        S_new = wt[..., :, None] * S + kv
        return S_new, out

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (rf, kf, vf, wf))
    state_new, outs = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(outs, 0, 1), state_new  # (B,T,H,hd)


def _use_kernel() -> bool:
    return os.environ.get("REPRO_USE_PALLAS", "0") == "1"


def _use_chunked() -> bool:
    """Chunked-parallel WKV (matmul form) — used by the dry-run lowering.

    The sequential scan is exact but compiles one while-loop per layer with
    T iterations (pathological for the unrolled 512-device dry-run compile,
    and invisible to XLA's cost analysis).  The chunked form computes the
    same recurrence as NC unrolled blocks of within-chunk quadratic
    attention + cross-chunk state propagation — matching the Pallas
    kernel's blocking, with FLOPs ~1.5-2x the true linear cost (recorded in
    EXPERIMENTS §Roofline).  Numerics note: the factored within-chunk decay
    exp(L_t - L_s) can underflow for adversarial decays; the exact
    sequential path stays the default for execution and the Pallas kernel
    (sequential inner loop in VMEM) for TPU production.
    """
    return os.environ.get("REPRO_RWKV_CHUNKED", "0") == "1"


def _wkv_chunked(r, k, v, w, u, state, chunk: int = 256):
    """Chunked-parallel WKV6: same recurrence as _wkv_scan, in matmul form.

    r,k,v,w: (B,T,H,hd); u: (H,hd); state: (B,H,hd,hd) fp32.
    """
    B, T, H, hd = r.shape
    C = min(chunk, T)
    pad = (-T) % C
    if pad:
        z = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = z(r), z(k), z(v)
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)),
                    constant_values=1.0)
    NC = (T + pad) // C

    def cshape(a):  # (B,T,H,hd) -> (B,NC,C,H,hd) in fp32
        return a.astype(jnp.float32).reshape(B, NC, C, H, hd)

    rc, kc, vc, wc = cshape(r), cshape(k), cshape(v), cshape(w)
    logw = jnp.log(jnp.clip(wc, 1e-38))
    L = jnp.cumsum(logw, axis=2)                    # inclusive within chunk
    Lprev = L - logw                                # exclusive (L_{t-1})
    uf = u.astype(jnp.float32)

    S = state.astype(jnp.float32)
    outs = []
    for c in range(NC):                             # unrolled chunk blocks
        rcc, kcc, vcc = rc[:, c], kc[:, c], vc[:, c]
        Lc, Lp = L[:, c], Lprev[:, c]
        # intra-chunk: A[t,s] = sum_c r_t k_s exp(Lp_t - L_s), s < t
        P = rcc * jnp.exp(Lp)                       # (B,C,H,hd)
        Q = kcc * jnp.exp(-Lc)
        A = jnp.einsum("bthc,bshc->bhts", P, Q)
        tri = jnp.tril(jnp.ones((C, C), bool), -1)
        A = jnp.where(tri[None, None], A, 0.0)
        intra = jnp.einsum("bhts,bshj->bthj", A, vcc)
        # diagonal (bonus u) term
        diag = jnp.einsum("bthc,bthc->bth", rcc * uf[None, None], kcc)
        intra = intra + diag[..., None] * vcc
        # inter-chunk: r_t . diag(exp(Lp_t)) S_in
        inter = jnp.einsum("bthc,bhcj->bthj", P, S)
        outs.append(intra + inter)
        # state update: S = diag(exp(L_last)) S + sum_s diag(exp(L_last-L_s)) kv_s
        Llast = Lc[:, -1]                           # (B,H,hd)
        K2 = kcc * jnp.exp(Llast[:, None] - Lc)
        S = jnp.exp(Llast)[..., None] * S + jnp.einsum(
            "bshc,bshj->bhcj", K2, vcc)
    out = jnp.concatenate(outs, axis=1)[:, :T]
    return out, S


def time_mix(cfg, p, x, state, mode: str):
    """x: (B,T,d) (T=1 for decode). Returns (y, new_state)."""
    B, T, d = x.shape
    H, hd = cfg.num_heads, cfg.rwkv_head_dim

    prev = state["tm_prev"]  # (B,d)
    x_shift = jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)
    sx = x_shift - x
    xw, xk, xv, xr, xg = _ddlerp(p, x, sx)

    r = (xr @ p["wr"]).reshape(B, T, H, hd)
    k = (xk @ p["wk"]).reshape(B, T, H, hd)
    v = (xv @ p["wv"]).reshape(B, T, H, hd)
    g = jax.nn.silu(xg @ p["wg"])
    w = _decay(p, xw).reshape(B, T, H, hd)
    u = p["bonus_u"].astype(jnp.float32)

    if _use_kernel() and T > 1:
        from repro.kernels import ops
        out, S = ops.rwkv6_scan(r, k, v, w, u, state["wkv"])
    elif _use_chunked() and T > 1:
        out, S = _wkv_chunked(r, k, v, w, u, state["wkv"],
                              chunk=int(os.environ.get("REPRO_RWKV_CHUNK",
                                                       "256")))
    else:
        out, S = _wkv_scan(r, k, v, w, u, state["wkv"])

    # per-head group norm
    out = out.reshape(B, T, H, hd)
    mean2 = jnp.mean(out * out, axis=-1, keepdims=True)
    out = out * jax.lax.rsqrt(mean2 + cfg.norm_eps)
    out = out.reshape(B, T, d).astype(x.dtype)
    out = out * (1.0 + p["ln_x"]) * g
    y = out @ p["wo"]

    new_state = dict(state)
    new_state["tm_prev"] = x[:, -1]
    new_state["wkv"] = S
    return y, new_state


def channel_mix(cfg, p, x, state, mode: str):
    prev = state["cm_prev"]
    x_shift = jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)
    sx = x_shift - x
    xk = x + sx * p["mu_k"]
    xr = x + sx * p["mu_r"]
    k = jnp.square(jax.nn.relu(xk @ p["wk"]))
    y = jax.nn.sigmoid(xr @ p["wr"]) * (k @ p["wv"])
    new_state = dict(state)
    new_state["cm_prev"] = x[:, -1]
    return y, new_state


def rwkv_block_specs(cfg):
    return {
        "ln1": spec((cfg.d_model,), ("embed",), "zeros"),
        "tm": timemix_specs(cfg),
        "ln2": spec((cfg.d_model,), ("embed",), "zeros"),
        "cm": channelmix_specs(cfg),
    }


def rwkv_block(cfg, p, x, state, mode: str):
    h, state = time_mix(cfg, p["tm"], rms_norm(x, p["ln1"], cfg.norm_eps),
                        state, mode)
    x = x + h
    h, state = channel_mix(cfg, p["cm"], rms_norm(x, p["ln2"], cfg.norm_eps),
                           state, mode)
    return x + h, state
