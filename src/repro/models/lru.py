"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Block: x -> [linear branch + gelu gate branch]; the linear branch goes
through a width-4 causal depthwise conv then the Real-Gated LRU:

    r_t = sigmoid(W_a x_t + b_a)         (recurrence gate)
    i_t = sigmoid(W_i x_t + b_i)         (input gate)
    log a_t = -c * softplus(Lambda) * r_t       (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training/prefill uses ``jax.lax.associative_scan`` over the sequence
(parallel prefix); decode is a single-step update.  State: h (B,w) fp32 +
conv tail (B,3,w).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import adtype, spec

LRU_C = 8.0
CONV_W = 4


def recurrent_specs(cfg):
    d, w = cfg.d_model, cfg.lru_width
    return {
        "wx": spec((d, w), ("embed", "mlp")),
        "wgate": spec((d, w), ("embed", "mlp")),
        "conv_w": spec((CONV_W, w), (None, "mlp"), scale=0.5),
        "wa": spec((w, w), ("mlp", "mlp_out")),
        "ba": spec((w,), ("mlp",), "zeros"),
        "wi": spec((w, w), ("mlp", "mlp_out")),
        "bi": spec((w,), ("mlp",), "zeros"),
        "lam": spec((w,), ("mlp",), "uniform_decay"),
        "wo": spec((w, d), ("mlp", "embed")),
    }


def init_lru_state(cfg, batch: int):
    w = cfg.lru_width
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, CONV_W - 1, w), adtype(cfg)),
    }


def _causal_conv(p, xb, conv_state):
    """Depthwise causal conv width 4. xb: (B,T,w); conv_state: (B,3,w)."""
    ext = jnp.concatenate([conv_state, xb], axis=1)  # (B,T+3,w)
    T = xb.shape[1]
    out = sum(ext[:, i:i + T] * p["conv_w"][i] for i in range(CONV_W))
    new_state = ext[:, -(CONV_W - 1):]
    return out, new_state


def _lru_scan(a, b, h0):
    """h_t = a_t h_{t-1} + b_t via associative scan; h0: (B,w)."""
    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    aa, bb = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = aa * h0[:, None] + bb
    return h


def recurrent_block(cfg, p, x, state, mode: str):
    """x: (B,T,d) -> (y, new_state)."""
    xb = x @ p["wx"]
    gate = jax.nn.gelu(x @ p["wgate"])
    xc, conv_state = _causal_conv(p, xb, state["conv"])

    r = jax.nn.sigmoid(xc @ p["wa"] + p["ba"]).astype(jnp.float32)
    i = jax.nn.sigmoid(xc @ p["wi"] + p["bi"]).astype(jnp.float32)
    log_a = -LRU_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.clip(1.0 - a * a, 1e-12)) * (
        i * xc.astype(jnp.float32))

    if x.shape[1] == 1:  # decode
        h = a[:, 0] * state["h"] + b[:, 0]
        h_seq = h[:, None]
    else:
        h_seq = _lru_scan(a, b, state["h"])
        h = h_seq[:, -1]

    y = (gate * h_seq.astype(x.dtype)) @ p["wo"]
    return y, {"h": h, "conv": conv_state}
