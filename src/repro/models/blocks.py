"""Unified decoder/encoder block: pre-norm temporal part + (MoE-)FFN.

Block kinds (cfg.layer_pattern entries):
  full       — causal full self-attention
  local      — sliding-window self-attention (ring cache)
  cross      — self-attention + cross-attention to a source sequence
  recurrent  — RG-LRU temporal block (hybrid family)
  rwkv       — RWKV6 time-mix/channel-mix (ssm family; FFN = channel-mix)
  enc        — bidirectional self-attention (encoder stacks)

Every ``block_apply`` returns ``(x, new_cache, aux)`` where aux is the MoE
load-balance loss contribution (0 otherwise).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import lru, moe, rwkv
from repro.models.common import ffn_apply, ffn_specs, norm_spec, rms_norm


def block_specs(cfg, kind: str):
    if kind == "rwkv":
        return rwkv.rwkv_block_specs(cfg)
    d = cfg.d_model
    s = {"ln1": norm_spec(d), "ln2": norm_spec(d)}
    if kind == "recurrent":
        s["rec"] = lru.recurrent_specs(cfg)
    else:
        s["attn"] = attn.attn_specs(cfg)
    if kind == "cross":
        s["lnx"] = norm_spec(d)
        s["xattn"] = attn.cross_attn_specs(cfg)
    if cfg.num_experts:
        s["ffn"] = moe.moe_specs(cfg)
    else:
        s["ffn"] = ffn_specs(d, cfg.d_ff)
    return s


def init_block_cache(cfg, kind: str, batch: int, max_seq: int, *,
                     pages: int = 0, page_size: int = 0, kv_dtype=None):
    """Zeroed decode cache for one block.

    ``pages > 0`` selects the paged layout for attention KV: page pools
    shared by all slots instead of per-slot dense rows.  Recurrent/RWKV
    state and cross-attention KV stay dense per slot (O(1)/write-once).
    ``kv_dtype`` picks the page-pool storage format (int8/fp8 modes add
    per-page scale tensors; see ``attn.init_paged_self_cache``).
    """
    if kind == "rwkv":
        return rwkv.init_rwkv_state(cfg, batch)
    if kind == "recurrent":
        return lru.init_lru_state(cfg, batch)
    if pages:
        c = attn.init_paged_self_cache(cfg, pages, page_size,
                                       kv_dtype=kv_dtype)
    else:
        c = attn.init_self_cache(cfg, kind, batch, max_seq)
    if kind == "cross":
        src = cfg.encoder_seq or cfg.cross_source_seq
        z = jnp.zeros((batch, src, cfg.num_kv_heads, cfg.head_dim),
                      jnp.dtype(cfg.dtype))
        c["ck"], c["cv"] = z, z
    return c


def _freeze(live, new, old):
    """Per-request state freeze: keep old state where live==False."""
    if live is None:
        return new
    def sel(n, o):
        mask = live.reshape((live.shape[0],) + (1,) * (n.ndim - 1))
        return jnp.where(mask, n, o.astype(n.dtype))
    return jax.tree.map(sel, new, old)


def block_apply(cfg, kind: str, p, x, *, mode: str, positions,
                cache=None, source=None, max_seq: int = 0,
                window_override: int = 0, live=None, pt=None):
    if kind == "rwkv":
        state = cache if cache is not None else rwkv.init_rwkv_state(
            cfg, x.shape[0])
        y, new_state = rwkv.rwkv_block(cfg, p, x, state, mode)
        if mode == "train":
            new_state = None
        elif mode == "decode":
            new_state = _freeze(live, new_state, state)
        return y, new_state, 0.0

    aux = 0.0
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind == "recurrent":
        state = cache if cache is not None else lru.init_lru_state(
            cfg, x.shape[0])
        y, new_cache = lru.recurrent_block(cfg, p["rec"], h, state, mode)
        if mode == "train":
            new_cache = None
        elif mode == "decode":
            new_cache = _freeze(live, new_cache, state)
    else:
        self_cache = None
        if cache is not None:
            self_cache = {k: cache[k]
                          for k in ("k", "v", "kp", "vp", "ks", "vs")
                          if k in cache}
        y, new_cache = attn.self_attention(
            cfg, p["attn"], h, kind=("full" if kind in ("cross", "enc")
                                     else kind),
            mode=mode, positions=positions, cache=self_cache,
            window_override=window_override, max_seq=max_seq,
            causal=(kind != "enc"), pt=pt)
    x = x + y

    if kind == "cross":
        h = rms_norm(x, p["lnx"], cfg.norm_eps)
        if mode == "decode":
            ckv = {"ck": cache["ck"], "cv": cache["cv"]}
        else:
            ckv = attn.compute_cross_kv(cfg, p["xattn"], source)
        y = attn.cross_attention(cfg, p["xattn"], h, ckv)
        x = x + y
        if new_cache is not None:
            new_cache = dict(new_cache)
            new_cache.update(ckv)

    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.num_experts:
        y, aux = moe.moe_ffn(cfg, p["ffn"], h)
    else:
        y = ffn_apply(p["ffn"], h, d_ff=cfg.d_ff)
    return x + y, new_cache, aux
