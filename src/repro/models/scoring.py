"""Shared-prefix candidate scoring — beyond-paper optimization (§Perf).

The paper scores the n draft candidates under pi_B with "a single forward
pass", but a cache-based implementation naively materializes n copies of the
committed KV cache (the baseline engine does exactly that, via
``repeat_cache``).  This module scores all n candidates against ONE shared
cache with a two-block attention:

    scores(q_cand, [shared_cache  |  own_candidate_prefix])

so the committed prefix is read once per request instead of n times, and the
n* cache-copy HBM footprint disappears.  Scoring is read-only (no cache
writes), so the whole pass is a pure map — ideal for XLA.

Supports every family: attention caches (full / ring-buffer local / cross)
via the joint softmax below; recurrent families (rwkv / RG-LRU) broadcast
their O(1) state n-ways and run the normal sequence path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import lru, moe, rwkv
from repro.models.common import (adtype, apply_rope, embed_tokens, ffn_apply,
                                 rms_norm, unembed)

NEG = -1e30


def _slot_abs_positions(pos, size):
    """abs position held by ring slot j given next-write position ``pos``.

    a_j = pos-1 - ((pos-1-j) mod size); a_j < 0 means the slot is empty.
    Works for full caches too (size >= pos -> a_j = j for j < pos).
    """
    j = jnp.arange(size)[None, :]
    p1 = pos[:, None] - 1
    return p1 - jnp.mod(p1 - j, size)


def score_attention(cfg, p, x, *, cache, pos, n, kind, window_override=0):
    """x: (B*n, L, d); cache: {'k','v'} (B, S, KV, hd); pos: (B,).

    Returns attention output (B*n, L, H, hd-flattened d).  No cache writes.
    """
    BN, L, _ = x.shape
    B = pos.shape[0]
    N = BN // B
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    G = H // KV
    scale = hd ** -0.5
    window = cfg.window_size if kind == "local" else 0
    if window_override:
        window = window_override if window == 0 else min(window,
                                                         window_override)

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))

    qabs = jnp.repeat(pos, N)[:, None] + jnp.arange(L)[None, :]  # (BN, L)
    q = apply_rope(q, qabs, cfg.rope_theta)
    k = apply_rope(k, qabs, cfg.rope_theta)

    qr = q.reshape(B, N, L, KV, G, hd)
    kr = k.reshape(B, N, L, KV, hd)
    vr = v.reshape(B, N, L, KV, hd)
    ck, cv = cache["k"], cache["v"]
    S = ck.shape[1]

    # --- scores against the shared committed cache ---------------------
    sc = jnp.einsum("bnlkgh,bskh->bnkgls", qr, ck,
                    preferred_element_type=jnp.float32) * scale
    a = _slot_abs_positions(pos, S)                     # (B, S)
    qa = pos[:, None] + jnp.arange(L)[None, :]          # (B, L)
    mask_c = (a[:, None, :] >= 0) & (a[:, None, :] < pos[:, None, None])
    if window:
        mask_c &= a[:, None, :] > qa[:, :, None] - window
    # mask_c: (B, 1 or L, S) -> broadcast over (B, N, KV, G, L, S)
    sc = sc + jnp.where(mask_c[:, None, None, None, :, :], 0.0, NEG)

    # --- causal scores within each candidate ----------------------------
    ss = jnp.einsum("bnlkgh,bnmkh->bnkglm", qr, kr,
                    preferred_element_type=jnp.float32) * scale
    li = jnp.arange(L)
    mask_s = li[:, None] >= li[None, :]
    if window:
        mask_s &= li[:, None] - li[None, :] < window
    ss = ss + jnp.where(mask_s[None, None, None, None], 0.0, NEG)

    # --- joint softmax over [cache | own prefix] -------------------------
    joint = jnp.concatenate([sc, ss], axis=-1)
    probs = jax.nn.softmax(joint, axis=-1).astype(x.dtype)
    pc, ps = probs[..., :S], probs[..., S:]
    out = jnp.einsum("bnkgls,bskh->bnlkgh", pc, cv) + \
        jnp.einsum("bnkglm,bnmkh->bnlkgh", ps, vr)
    out = out.reshape(BN, L, H, hd)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))


def _repeat_b(tree, n):
    return jax.tree.map(lambda a: jnp.repeat(a, n, axis=0), tree)


def score_block(cfg, kind, p, x, *, cache, pos, n, window_override=0):
    """One decoder block in score mode. Returns x only (no cache)."""
    if kind == "rwkv":
        state = _repeat_b(cache, n)
        y, _ = rwkv.rwkv_block(cfg, p, x, state, "extend")
        return y, 0.0
    aux = 0.0
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind == "recurrent":
        state = _repeat_b(cache, n)
        y, _ = lru.recurrent_block(cfg, p["rec"], h, state, "extend")
    else:
        self_cache = {"k": cache["k"], "v": cache["v"]}
        y = score_attention(cfg, p["attn"], h, cache=self_cache, pos=pos, n=n,
                            kind=("full" if kind in ("cross", "enc")
                                  else kind),
                            window_override=window_override)
    x = x + y
    if kind == "cross":
        h = rms_norm(x, p["lnx"], cfg.norm_eps)
        ckv = {"ck": jnp.repeat(cache["ck"], n, 0),
               "cv": jnp.repeat(cache["cv"], n, 0)}
        x = x + attn.cross_attention(cfg, p["xattn"], h, ckv)
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.num_experts:
        y, aux = moe.moe_ffn(cfg, p["ffn"], h)
    else:
        y = ffn_apply(p["ffn"], h)
    return x + y, aux


def score_candidates(model, params, cache, pending, pos, cand_tokens, *,
                     return_rewards: bool = False):
    """Score n candidate steps against one shared committed cache.

    cand_tokens: (B, n, L) PAD-padded; pending/pos: (B,) engine invariant
    (cache holds positions < pos; ``pending`` sits at pos, not yet cached).

    Returns (logp (B,n)[, rewards (B,n)]) — log pi(cand | prefix) and the
    PRM reward at each candidate's last real token.
    """
    cfg = model.cfg
    B, n, L = cand_tokens.shape
    feeds = jnp.concatenate(
        [jnp.repeat(pending[:, None, None], n, axis=1), cand_tokens],
        axis=2).reshape(B * n, L + 1)
    x = embed_tokens(cfg, params["embed"], feeds)

    def blk(kind, bp, h, c):
        return score_block(cfg, kind, bp, h, cache=c, pos=pos, n=n,
                           window_override=cfg.serve_window_override)

    aux = 0.0
    if model.repeats:
        def body(carry, xs):
            h = carry
            bp, csl = xs
            a = 0.0
            for i, kind in enumerate(model.pattern):
                h, ai = blk(kind, bp[f"p{i}"], h, csl[f"p{i}"])
                a += ai
            return h, a

        x, _ = jax.lax.scan(body, x, (params["blocks"], cache["blocks"]))
    if model.remainder:
        for i, kind in enumerate(model.remainder):
            x, _ = blk(kind, params["rem"][f"r{i}"], x,
                       cache["rem"][f"r{i}"])
    x = rms_norm(x, params["final_ln"], cfg.norm_eps)

    # log-probs of the candidate tokens (fused gather over vocab)
    from repro.kernels import ops
    w = params["embed"].get("unembed")
    if w is None:
        w = params["embed"]["embedding"].T
    labels = cand_tokens.reshape(B * n, L)
    lp_tok = ops.logprob_gather(x[:, :L], w, jnp.maximum(labels, 0),
                                cfg.vocab_size)
    live = labels != 0
    logp = jnp.sum(jnp.where(live, lp_tok, 0.0), axis=1).reshape(B, n)
    if not return_rewards:
        return logp
    lengths = jnp.sum(live, axis=1)                      # (B*n,)
    h_at_end = jnp.take_along_axis(
        x, lengths[:, None, None].astype(jnp.int32), axis=1)[:, 0]
    rewards = model.reward_from_hidden(params, h_at_end).reshape(B, n)
    return logp, rewards
