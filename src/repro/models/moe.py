"""Mixture-of-Experts FFN: top-k routing, shared experts, expert parallelism.

Distribution model (DESIGN.md §5): the routed experts are sharded over the
``model`` mesh axis (expert parallelism).  The layer runs under
``jax.shard_map`` so the dispatch is *local*: every device computes, for its
local token shard and its local expert shard, a capacity-bounded
gather -> grouped-matmul -> scatter, then ``psum`` over the ``model`` axis
combines each token's top-k expert outputs.  This avoids the O(T*E*C) GShard
one-hot dispatch tensor, which is infeasible at kimi-k2 scale.

Experts are padded to a multiple of the model-axis size (e.g. qwen2-moe's 60
routed experts are padded to 64); padding experts are masked out of the
router softmax.

Without a mesh (CPU unit tests) the same local math runs on the full arrays.
"""
from __future__ import annotations

import math
import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec, spec
from repro.distributed import context as dctx


def _shard_map(fn, *, mesh, in_specs, out_specs):
    """jax.shard_map (jax >= 0.6, check_vma) or the experimental API
    (jax 0.4.x, check_rep) — replication checking off in both."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(fn, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)


def padded_experts(cfg, model_axis: int = 16) -> int:
    m = max(model_axis, 1)
    return (cfg.num_experts + m - 1) // m * m


def moe_specs(cfg):
    d, ff = cfg.d_model, cfg.moe_d_ff
    e = padded_experts(cfg)
    s = {
        "router": spec((d, e), ("embed", "expert_in")),
        "we_gate": spec((e, d, ff), ("expert", "embed", "expert_mlp")),
        "we_up": spec((e, d, ff), ("expert", "embed", "expert_mlp")),
        "we_down": spec((e, ff, d), ("expert", "expert_mlp", "embed")),
    }
    if cfg.num_shared_experts:
        sff = cfg.num_shared_experts * cfg.moe_d_ff
        s["shared_gate"] = spec((d, sff), ("embed", "mlp"))
        s["shared_up"] = spec((d, sff), ("embed", "mlp"))
        s["shared_down"] = spec((sff, d), ("mlp", "embed"))
    return s


# ---------------------------------------------------------------------------
# Local (per-shard) expert computation
# ---------------------------------------------------------------------------

def _local_expert_ffn(cfg, p_local, x, top_w, top_e, e0, e_local, capacity):
    """x: (T,d); top_w/top_e: (T,k); experts [e0, e0+e_local) are local.

    Returns this shard's additive contribution (T,d) for its local experts.
    """
    T, d = x.shape
    k = top_e.shape[1]
    slots = T * k
    flat_e = top_e.reshape(slots) - e0                 # local expert index
    flat_w = top_w.reshape(slots)
    tok_of_slot = jnp.repeat(jnp.arange(T), k)
    valid = (flat_e >= 0) & (flat_e < e_local)
    bucket = jnp.where(valid, flat_e, e_local)         # drop bucket at end

    # rank of each slot within its expert bucket (stable counting sort)
    order = jnp.argsort(bucket, stable=True)           # (slots,)
    sorted_bucket = bucket[order]
    counts = jnp.bincount(bucket, length=e_local + 1)
    starts = jnp.concatenate([jnp.zeros(1, counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(slots) - starts[sorted_bucket]   # rank among same expert

    keep = (sorted_bucket < e_local) & (rank < capacity)
    buf_pos = jnp.where(keep, sorted_bucket * capacity + rank,
                        e_local * capacity)            # overflow row
    src_tok = tok_of_slot[order]

    xbuf = jnp.zeros((e_local * capacity + 1, d), x.dtype)
    xbuf = xbuf.at[buf_pos].set(jnp.where(keep[:, None], x[src_tok], 0.0))
    xb = xbuf[:-1].reshape(e_local, capacity, d)

    gate = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xb, p_local["we_gate"]))
    up = jnp.einsum("ecd,edf->ecf", xb, p_local["we_up"])
    h = jnp.einsum("ecf,efd->ecd", gate * up, p_local["we_down"])
    h = h.reshape(e_local * capacity, d)
    h = jnp.concatenate([h, jnp.zeros((1, d), h.dtype)], axis=0)

    contrib = h[buf_pos] * (flat_w[order] * keep)[:, None]
    out = jnp.zeros((T, d), x.dtype).at[src_tok].add(contrib)
    return out


def _route(cfg, router_w, x):
    """Router: softmax over real experts, top-k, renormalized weights."""
    e_pad = router_w.shape[1]
    logits = (x @ router_w).astype(jnp.float32)
    mask = jnp.arange(e_pad) < cfg.num_experts
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, cfg.experts_per_token)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)
    # load-balance aux loss (Switch-style): E * sum_e f_e * p_e
    T = x.shape[0]
    me = jnp.mean(probs, axis=0)
    one_hot_load = jnp.zeros((T, e_pad)).at[
        jnp.arange(T)[:, None], top_e].add(1.0)
    fe = jnp.mean(one_hot_load, axis=0) / cfg.experts_per_token
    aux = cfg.num_experts * jnp.sum(fe * me)
    return top_w.astype(x.dtype), top_e, aux


def _shared_ffn(p, x):
    gate = jax.nn.silu(x @ p["shared_gate"])
    return (gate * (x @ p["shared_up"])) @ p["shared_down"]


def moe_ffn(cfg, p, x):
    """x: (B,S,d) -> (y, aux_loss).  shard_map EP when a mesh is active."""
    B, S, d = x.shape
    xf = x.reshape(B * S, d)
    mesh = dctx.get_mesh()
    e_pad = p["router"].shape[1]

    if mesh is None or "model" not in mesh.axis_names or mesh.size == 1:
        cap = _capacity(cfg, B * S, e_pad)
        top_w, top_e, aux = _route(cfg, p["router"].astype(xf.dtype), xf)
        y = _local_expert_ffn(cfg, p, xf, top_w, top_e, 0, e_pad, cap)
        if cfg.num_shared_experts:
            y = y + _shared_ffn(p, xf)
        return y.reshape(B, S, d), aux

    from jax.sharding import PartitionSpec as P
    tp = mesh.shape["model"]
    batch_axes = tuple(a for a in mesh.axis_names if a != "model")
    dp = math.prod(mesh.shape[a] for a in batch_axes)
    e_local = e_pad // tp

    # Two expert-parallel execution modes (EXPERIMENTS §Perf H2):
    #   gather — tokens sharded over data, experts over model; each device
    #            needs the FULL per-expert FFN weights (all-gathered over
    #            data when the params are EPxFSDP sharded).  Right for
    #            training/prefill (millions of tokens).
    #   repl   — tokens replicated, expert FFN dim sharded over data: no
    #            weight gathers at all, collectives are one activation psum.
    #            Right for decode, where T is tiny and the per-layer weight
    #            gather (GBs) dwarfs the compute.
    mode = os.environ.get("REPRO_MOE_MODE", "auto")
    if mode == "auto":
        mode = "repl" if (B * S) <= 2048 or (B * S) % dp != 0 else "gather"
    ff = p["we_gate"].shape[2]
    if mode == "repl" and (ff % dp != 0):
        mode = "gather"
    if mode == "gather" and (B * S) % dp != 0:
        batch_axes, dp = (), 1

    shared = None
    if cfg.num_shared_experts:
        shared = {"shared_gate": p["shared_gate"],
                  "shared_up": p["shared_up"],
                  "shared_down": p["shared_down"]}

    if mode == "repl":
        cap = _capacity(cfg, B * S, e_pad)
        psum_axes = tuple(batch_axes) + ("model",)

        def shard_fn(xl, router_w, wg, wu, wd, sh):
            # xl replicated; wg/wu: (E_local, d, ff_local); wd transposed
            top_w, top_e, aux = _route(cfg, router_w.astype(xl.dtype), xl)
            e0 = jax.lax.axis_index("model") * e_local
            p_local = {"we_gate": wg.astype(xl.dtype),
                       "we_up": wu.astype(xl.dtype),
                       "we_down": wd.astype(xl.dtype)}
            y = _local_expert_ffn(cfg, p_local, xl, top_w, top_e, e0,
                                  e_local, cap)
            if sh is not None:
                y = y + _shared_ffn(
                    {k: v.astype(xl.dtype) for k, v in sh.items()}, xl)
            return jax.lax.psum(y, psum_axes), \
                jax.lax.pmean(aux, psum_axes)

        data_ax = (batch_axes if len(batch_axes) > 1 else
                   (batch_axes[0] if batch_axes else None))
        y, aux = _shard_map(
            shard_fn, mesh=mesh,
            in_specs=(P(None, None), P(None, None),
                      P("model", None, data_ax), P("model", None, data_ax),
                      P("model", data_ax, None),
                      (None if shared is None else
                       {"shared_gate": P(None, ("model",) + batch_axes
                                         if batch_axes else "model"),
                        "shared_up": P(None, ("model",) + batch_axes
                                       if batch_axes else "model"),
                        "shared_down": P(("model",) + batch_axes
                                         if batch_axes else "model",
                                         None)})),
            out_specs=(P(None, None), P()),
        )(xf, p["router"], p["we_gate"], p["we_up"], p["we_down"], shared)
        return y.reshape(B, S, d), jnp.mean(aux)

    t_local = (B * S) // dp
    cap = _capacity(cfg, t_local, e_pad)

    def shard_fn(xl, router_w, wg, wu, wd, sh):
        # xl: (T_local, d) (replicated over 'model'); w*: local expert shard
        top_w, top_e, aux = _route(cfg, router_w.astype(xl.dtype), xl)
        e0 = jax.lax.axis_index("model") * e_local
        p_local = {"we_gate": wg.astype(xl.dtype),
                   "we_up": wu.astype(xl.dtype),
                   "we_down": wd.astype(xl.dtype)}
        y = _local_expert_ffn(cfg, p_local, xl, top_w, top_e, e0, e_local,
                              cap)
        if sh is not None:
            y = y + _shared_ffn(
                {k: v.astype(xl.dtype) for k, v in sh.items()}, xl)
        y = jax.lax.psum(y, "model")
        aux = jax.lax.pmean(aux, "model")
        return y, aux

    if not batch_axes:
        tok_spec = P(None, None)
    else:
        tok_spec = P(batch_axes if len(batch_axes) > 1 else batch_axes[0],
                     None)
    y, aux = _shard_map(
        shard_fn, mesh=mesh,
        in_specs=(tok_spec, P(None, None), P("model", None, None),
                  P("model", None, None), P("model", None, None),
                  (None if shared is None else
                   {"shared_gate": P(None, "model"),
                    "shared_up": P(None, "model"),
                    "shared_down": P("model", None)})),
        out_specs=(tok_spec, P()),
    )(xf, p["router"], p["we_gate"], p["we_up"], p["we_down"], shared)
    return y.reshape(B, S, d), jnp.mean(aux)


def _capacity(cfg, tokens_local: int, e_pad: int) -> int:
    # capacity per expert, w.r.t. the *real* expert count (padding experts
    # receive no traffic), rounded up to 8 for clean TPU tiling.
    c = int(math.ceil(tokens_local * cfg.experts_per_token / cfg.num_experts
                      * cfg.capacity_factor))
    return max(8, int(math.ceil(c / 8)) * 8)
