"""GQA attention: full / sliding-window (ring-buffer cache) / cross.

Three execution modes per block:
  * ``train``   — full-sequence causal attention, no cache.
  * ``prefill`` — same math, additionally returns the populated KV cache.
  * ``decode``  — single-token query against the cache (per-request positions).

Local (sliding-window) layers keep a **ring buffer** cache of ``window``
entries, so long_500k decode stores O(window), not O(seq), per local layer.
Keys are cached rope-applied (absolute positions), the standard TPU idiom.

On TPU the train/prefill path dispatches to the Pallas flash-attention kernel
(``repro.kernels.ops.flash_attention``); the pure-jnp path here doubles as its
oracle and as the CPU/dry-run implementation.
"""
from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed import tp as _tp
from repro.kernels import quant
from repro.models.common import ParamSpec, adtype, apply_rope, spec

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

def attn_specs(cfg, *, cross: bool = False):
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    s = {
        "wq": spec((d, H, hd), ("embed", "heads", "head")),
        "wk": spec((d, KV, hd), ("embed", "kv", "head")),
        "wv": spec((d, KV, hd), ("embed", "kv", "head")),
        "wo": spec((H, hd, d), ("heads", "head", "embed")),
    }
    return s


# ---------------------------------------------------------------------------
# Core attention math (pure jnp; GQA grouped einsum)
# ---------------------------------------------------------------------------

def _score_dtype():
    # §Perf H1 iter-2 knob: bf16 score buffers halve the S^2 HBM traffic of
    # the non-flash (XLA) attention path; fp32 stays the default.
    return jnp.bfloat16 if os.environ.get("REPRO_ATTN_SCORES_BF16") == "1" \
        else jnp.float32


def gqa_attention(q, k, v, mask, scale):
    """q: (B,Sq,H,hd) k/v: (B,Sk,KV,hd) mask: (B or 1, Sq, Sk) boolean."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, k,
                        preferred_element_type=_score_dtype()) * scale
    scores = scores.astype(jnp.float32) \
        + jnp.where(mask[:, None, None], 0.0, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v)
    return out.reshape(B, Sq, H, hd)


def causal_mask(sq: int, sk: int, q_offset=0, window: int = 0):
    """(1, sq, sk) boolean mask. window>0 = sliding window."""
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(sk)[None, :]
    m = kpos <= qpos
    if window:
        m &= kpos > qpos - window
    return m[None]


def _use_flash() -> bool:
    return os.environ.get("REPRO_USE_PALLAS", "0") == "1"


def _full_seq_attention(q, k, v, scale, window: int, causal: bool = True):
    if _use_flash() and causal:
        from repro.kernels import ops
        return ops.flash_attention(q, k, v, causal=True, window=window,
                                   scale=scale)
    if causal:
        mask = causal_mask(q.shape[1], k.shape[1], window=window)
    else:
        mask = jnp.ones((1, q.shape[1], k.shape[1]), bool)
    return gqa_attention(q, k, v, mask, scale)


# ---------------------------------------------------------------------------
# Self-attention block
# ---------------------------------------------------------------------------

def init_self_cache(cfg, kind: str, batch: int, max_seq: int):
    """Zeroed cache pytree for one attention layer."""
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    size = _cache_len(cfg, kind, max_seq)
    z = jnp.zeros((batch, size, KV, hd), adtype(cfg))
    return {"k": z, "v": z}


def init_paged_self_cache(cfg, total_pages: int, page_size: int,
                          kv_dtype=None):
    """Paged cache for one attention layer: K/V page pools, no batch dim.

    Positions are stored *absolutely* (page of position p = block table
    entry ``p // page_size``) for every layer kind; sliding-window layers
    trade the dense ring buffer's O(window) rows for page-table sharing
    and get their locality back through the decode mask instead.

    ``kv_dtype`` selects the pool storage format (see
    :mod:`repro.kernels.quant`): ``None`` keeps the activation dtype,
    ``"bf16"`` is a plain half-width cast, and ``"int8"`` / ``"fp8"``
    store codes plus per-page per-kv-head float32 scale tensors
    (``ks``/``vs``, shaped ``(P, KV)``) that ride next to the pools in
    the cache pytree and through COW branching with them.
    """
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    dt = quant.pool_dtype(kv_dtype, adtype(cfg))
    z = jnp.zeros((total_pages, page_size, KV, hd), dt)
    out = {"kp": z, "vp": z}
    if quant.is_quantized(kv_dtype):
        sc = jnp.zeros((total_pages, KV), jnp.float32)
        out["ks"], out["vs"] = sc, sc
    return out


def _cache_len(cfg, kind: str, max_seq: int) -> int:
    if kind == "local" or (cfg.serve_window_override and kind in ("full", "cross")):
        w = cfg.window_size if kind == "local" else cfg.serve_window_override
        return min(w, max_seq)
    return max_seq


def self_attention(cfg, p, x, *, kind: str, mode: str,
                   positions, cache=None, window_override: int = 0,
                   max_seq: int = 0, causal: bool = True, pt=None):
    """Returns (out, new_cache).

    positions: (S,) for train/prefill (shared across batch); (B,) for decode.
    ``pt`` (B, nblk) selects the paged decode path when ``cache`` holds
    page pools ({'kp','vp'}) instead of per-slot dense rows ({'k','v'}).
    """
    B = x.shape[0]
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    scale = hd ** -0.5
    window = cfg.window_size if kind == "local" else 0
    if window_override:
        window = window_override if window == 0 else min(window, window_override)

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))

    if mode in ("train", "prefill"):
        pos = positions[None, :]  # (1,S)
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
        out = _full_seq_attention(q, k, v, scale, window, causal=causal)
        new_cache = None
        if mode == "prefill":
            new_cache = _fill_cache(cfg, kind, k, v, positions,
                                    max_seq or k.shape[1])
    else:  # decode: x is (B,1,d), positions (B,)
        pos_b = positions[:, None]  # (B,1)
        q = apply_rope(q, pos_b, cfg.rope_theta)
        k = apply_rope(k, pos_b, cfg.rope_theta)
        if pt is not None and "kp" in cache:
            from repro.kernels import ops
            if "ks" in cache:
                new_cache = _write_cache_paged_quant(cache, k, v,
                                                     positions, pt)
                out = ops.paged_attention_quant(
                    q, new_cache["kp"], new_cache["vp"], new_cache["ks"],
                    new_cache["vs"], pt, positions, window=window,
                    scale=scale)
            else:
                new_cache = _write_cache_paged(cache, k, v, positions, pt)
                out = ops.paged_attention(q, new_cache["kp"],
                                          new_cache["vp"], pt, positions,
                                          window=window, scale=scale)
        else:
            new_cache = _write_cache(cache, k, v, positions)
            mask = _decode_mask(new_cache["k"].shape[1], positions,
                                ring=(window > 0))  # (B,1,Sk)
            out = gqa_attention(q, new_cache["k"], new_cache["v"], mask,
                                scale)

    # Tensor-parallel output projection: when this trace holds a head
    # shard (wq gave us H/tp query heads), all-gather BOTH the per-head
    # attention outputs and wo's head dim, then run the full einsum —
    # exact concatenation followed by the identical contraction, so the
    # result is bitwise-equal to unsharded (a psum over partial wo
    # products would reorder float additions and is not).  Everything
    # above is per-head math on exact head shards: q/k/v projections
    # contract over the replicated d_model dim, rope / softmax / paged
    # gathers are head-independent, and the KV cache leaves are sharded
    # along the same kv-head axis the shard computes.
    wo = p["wo"]
    ax = _tp.axis()
    if ax is not None and out.shape[2] != H:
        out = jax.lax.all_gather(out, ax, axis=2, tiled=True)
        wo = jax.lax.all_gather(wo, ax, axis=0, tiled=True)
    y = jnp.einsum("bshk,hkd->bsd", out, wo.astype(x.dtype))
    return y, new_cache


def _fill_cache(cfg, kind, k, v, positions, max_seq):
    """Build the capacity-sized cache from prefill keys/values (rope'd)."""
    S = k.shape[1]
    size = _cache_len(cfg, kind, max_seq=max_seq)
    if size > S:  # pad to capacity; decode continues writing at pos >= S
        pad = [(0, 0), (0, size - S), (0, 0), (0, 0)]
        return {"k": jnp.pad(k, pad), "v": jnp.pad(v, pad)}
    if size == S:
        return {"k": k, "v": v}
    # ring buffer: slot j holds the latest position p with p % size == j;
    # prefill positions are arange(S) so cache index == position index.
    start = S - size
    idx = start + (jnp.arange(size) - start) % size
    return {"k": jnp.take(k, idx, axis=1), "v": jnp.take(v, idx, axis=1)}


def _write_cache(cache, k, v, positions):
    """Write the new (B,1,KV,hd) kv at per-request positions (ring aware)."""
    size = cache["k"].shape[1]
    slots = positions % size

    def upd(c, new, s):
        return jax.lax.dynamic_update_slice(c, new, (s, 0, 0))

    k_new = jax.vmap(upd)(cache["k"], k, slots)
    v_new = jax.vmap(upd)(cache["v"], v, slots)
    return {"k": k_new, "v": v_new}


def _write_cache_paged(cache, k, v, positions, pt):
    """Write the new (B,1,KV,hd) kv through the block table.

    Physical row of position p for request b is
    ``pt[b, p // ps] * ps + p % ps``.  Rows that are done (or never
    admitted) resolve to scratch/trash pages the host allocator set up, so
    the unconditional write stays harmless exactly as in the dense path.
    """
    kp, vp = cache["kp"], cache["vp"]
    P, ps = kp.shape[0], kp.shape[1]
    blk = jnp.minimum(positions // ps, pt.shape[1] - 1)
    page = jnp.take_along_axis(pt, blk[:, None], axis=1)[:, 0]
    rows = page * ps + positions % ps                      # (B,)

    def upd(pool, new):
        flat = pool.reshape((P * ps,) + pool.shape[2:])
        return flat.at[rows].set(
            new[:, 0].astype(pool.dtype)).reshape(pool.shape)

    out = dict(cache)
    out["kp"], out["vp"] = upd(kp, k), upd(vp, v)
    return out


def _write_cache_paged_quant(cache, k, v, positions, pt):
    """Quantized paged write: re-quantize the touched page whole.

    Each request's new (KV,hd) key/value lands in page
    ``pt[b, pos // ps]`` at row ``pos % ps``.  The page is read back,
    dequantized with its current scale, the new token's row inserted,
    rows *beyond* the write row zeroed (they are stale garbage from a
    previous occupant of the physical page and must not inflate the
    amax), and the page re-quantized against a fresh per-kv-head scale
    ``amax / QMAX``.  Re-quantization is exact for already-written rows
    whenever the scale is unchanged (``round(code) == code``), and the
    scale of a page only grows as rows fill in, so accumulated
    round-trip error stays bounded by one quantization step.

    The page-granularity scatter is race-free for the same reason the
    dense row scatter is: a slot's tail page is exclusively owned
    (published prefix pages are read-only by construction — writes only
    ever target positions past the matched prefix), branch writes land
    in per-branch scratch pages, and duplicate page indices only occur
    for the shared trash page whose content is garbage by design.
    """
    ps = cache["kp"].shape[1]
    dt = cache["kp"].dtype
    qmax = quant.QMAX["int8"] if dt == jnp.int8 else quant.QMAX["fp8"]
    blk = jnp.minimum(positions // ps, pt.shape[1] - 1)
    page = jnp.take_along_axis(pt, blk[:, None], axis=1)[:, 0]  # (B,)
    row = positions % ps                                        # (B,)
    lane = jnp.arange(ps)[None, :]                              # (1, ps)
    at_row = (lane == row[:, None])[:, :, None, None]
    valid = (lane <= row[:, None])[:, :, None, None]

    def upd(pool, sc, new):
        fp = pool[page].astype(jnp.float32) * sc[page][:, None, :, None]
        tok = new[:, 0].astype(jnp.float32)[:, None]            # (B,1,KV,hd)
        fp = jnp.where(at_row, tok, fp)
        fp = jnp.where(valid, fp, 0.0)
        amax = jnp.max(jnp.abs(fp), axis=(1, 3))                # (B, KV)
        nsc = jnp.maximum(amax, quant.EPS) / qmax
        codes = quant.quantize_codes(fp / nsc[:, None, :, None], dt)
        return pool.at[page].set(codes), sc.at[page].set(nsc)

    out = dict(cache)
    out["kp"], out["ks"] = upd(cache["kp"], cache["ks"], k)
    out["vp"], out["vs"] = upd(cache["vp"], cache["vs"], v)
    return out


def _decode_mask(sk: int, positions, *, ring: bool):
    """(B,1,Sk) validity mask for decode against a (ring) cache."""
    slots = jnp.arange(sk)[None]           # (1,Sk)
    pos = positions[:, None]               # (B,1)
    if not ring:
        return (slots <= pos)[:, None]
    # ring: slot j valid iff some p in (pos-size, pos] has p%size==j and p>=0
    filled = (slots <= pos) | (pos >= sk)
    return filled[:, None]


# ---------------------------------------------------------------------------
# Cross-attention (vlm / enc-dec): kv from a source sequence, cached once
# ---------------------------------------------------------------------------

def cross_attn_specs(cfg):
    return attn_specs(cfg)


def compute_cross_kv(cfg, p, source):
    """source: (B, S_src, d) -> cached cross kv (no rope)."""
    k = jnp.einsum("bsd,dhk->bshk", source, p["wk"].astype(source.dtype))
    v = jnp.einsum("bsd,dhk->bshk", source, p["wv"].astype(source.dtype))
    return {"ck": k, "cv": v}


def cross_attention(cfg, p, x, cross_kv):
    hd = cfg.head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    B, Sq = q.shape[:2]
    Sk = cross_kv["ck"].shape[1]
    mask = jnp.ones((1, Sq, Sk), bool)
    out = gqa_attention(q, cross_kv["ck"], cross_kv["cv"], mask, hd ** -0.5)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
