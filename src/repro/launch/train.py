"""Distributed training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --steps 200 --batch 16 --seq 256 [--synthetic] [--ckpt path]

On the CPU container this runs a real (small-batch) training loop on the
single device; on a TPU pod the same code path shards params/batch with the
production rules (pjit) — the mesh is chosen from the available device count.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.config import TrainConfig, get_config, reduced_config
from repro.data import SyntheticReasoningTask
from repro.data.lm import lm_batches, prefetch
from repro.distributed import context as dctx
from repro.distributed.sharding import (as_shardings, batch_pspec,
                                        param_pspecs)
from repro.models import build_model
from repro.optim import AdamW
from repro.train import make_train_step


def main() -> None:
    """CLI entry point (see module docstring for usage)."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced (smoke) variant of the arch")
    ap.add_argument("--synthetic", action="store_true",
                    help="use the synthetic reasoning task data")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    tcfg = TrainConfig(learning_rate=args.lr, total_steps=args.steps,
                       warmup_steps=max(10, args.steps // 20))
    model = build_model(cfg)
    opt = AdamW(tcfg)

    n_dev = len(jax.devices())
    if n_dev > 1:
        import math
        model_ax = math.gcd(n_dev, 16)
        mesh = jax.make_mesh((n_dev // model_ax, model_ax),
                             ("data", "model"))
        dctx.set_mesh(mesh)
        p_sh = as_shardings(param_pspecs(model.param_specs(), mesh, "train"),
                            mesh)
        params = jax.jit(model.init, out_shardings=p_sh)(
            jax.random.PRNGKey(tcfg.seed))
    else:
        params = model.init(jax.random.PRNGKey(tcfg.seed))
    opt_state = opt.init(params)

    step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0, 1))

    if args.synthetic:
        task = SyntheticReasoningTask(seed=tcfg.seed)
        it = (task.lm_batch(args.batch, args.seq) for _ in iter(int, 1))
    else:
        it = lm_batches(cfg.vocab_size, args.batch, args.seq, seed=tcfg.seed)
    it = prefetch(it)

    t0 = time.time()
    for i, batch in enumerate(it):
        if i >= args.steps:
            break
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt_state, m = step_fn(params, opt_state, batch)
        if i % args.log_every == 0 or i == args.steps - 1:
            dt = time.time() - t0
            print(f"step {i:5d} loss {float(m['loss']):8.4f} "
                  f"gnorm {float(m['grad_norm']):7.3f} "
                  f"lr {float(m['lr']):.2e} [{dt:6.1f}s]", flush=True)
    if args.ckpt:
        save_checkpoint(args.ckpt, params)
        print(f"saved checkpoint to {args.ckpt}")


if __name__ == "__main__":
    main()
