"""GSI serving launcher: train a draft/target/PRM triple on the synthetic
reasoning task (or load checkpoints), then serve queued requests through
the continuous-batching scheduler and report accuracy / acceptance /
throughput / latency-model numbers.

    PYTHONPATH=src python -m repro.launch.serve --requests 16 --n 4 \
        --method gsi --capacity 8 [--train-steps 300] \
        [--paged --replicas 2 --router affinity] [--sync | --async] \
        [--mesh-shape 1x2 | --tp 2]

``--replicas N`` serves through N data-parallel replicas (one engine,
page pool and radix index each) behind the preamble-affinity router.
``--mesh-shape DxM`` (or ``--tp M``) additionally carves the visible
devices into one disjoint submesh per replica and runs each replica's
*target* model tensor-parallel over the submesh's ``model`` axis
(draft and PRM stay replicated); tokens are bit-identical to the
unsharded engine.
Serving is asynchronous by default (``--async``): each scheduler keeps
one decode step in flight and overlaps harvest/admission with device
execution, and replicas are driven by a thread-per-replica fleet loop;
``--sync`` selects the lock-step loop (bit-identical tokens).  See
docs/SERVING.md for the full flag reference.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time

import jax
import numpy as np

from repro.config import GSIConfig, ModelConfig, TrainConfig
from repro.data import SyntheticReasoningTask, PAD
from repro.launch.mesh import carve_submeshes
from repro.serving import GSIScheduler, GSIServingEngine, ReplicaRouter
from repro.serving.router import HASH_TIERS, POLICIES
from repro.serving.latency import HW_V5E, LatencyModel, ModelCost
from repro.train import Trainer


#: XLA / allocator environment tuning (the olmax ``run.sh`` recipe):
#: a single host platform device (no fake TPU-CPU fan-out), step markers
#: at the outer while loop so profiles attribute whole decode steps, a
#: bounded preallocation fraction instead of the 75%-and-grow default,
#: and quiet allocator large-alloc warnings.  ``setdefault`` semantics —
#: anything the operator already exported wins.
TUNED_ENV = {
    "XLA_FLAGS": "--xla_force_host_platform_device_count=1 "
                 "--xla_step_marker_location="
                 "STEP_MARK_AT_TOP_LEVEL_WHILE_LOOP",
    "XLA_PYTHON_CLIENT_MEM_FRACTION": "0.8",
    "XLA_PYTHON_CLIENT_PREALLOCATE": "false",
    "TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD": "60000000000",
    "TF_CPP_MIN_LOG_LEVEL": "4",
}


def apply_tuned_env(env=None) -> dict:
    """Apply :data:`TUNED_ENV` to ``os.environ`` (or ``env``) and return
    the settings actually applied (operator-exported values win).

    Must run before the first ``import jax`` *use* touches a backend —
    XLA reads these at client construction, so ``--tuned-env`` applies
    them at the very top of ``main`` and prints the result.
    """
    target = os.environ if env is None else env
    applied = {}
    for key, val in TUNED_ENV.items():
        if target.setdefault(key, val) == val:
            applied[key] = val
    return applied


def parse_mesh_shape(text: str):
    """Parse ``"DxM"`` (e.g. ``1x2``) into a ``(data, model)`` tuple.

    ``--tp N`` is shorthand for ``--mesh-shape 1xN``; both feed
    :func:`repro.launch.mesh.carve_submeshes`, which slices the visible
    devices into one disjoint submesh per replica.
    """
    parts = text.lower().split("x")
    if len(parts) != 2:
        raise ValueError(f"--mesh-shape wants DxM (e.g. 1x2), got {text!r}")
    data, model = (int(p) for p in parts)
    if data < 1 or model < 1:
        raise ValueError(f"--mesh-shape axes must be >= 1, got {text!r}")
    return data, model


def toy_triple(vocab: int = 16):
    """Small draft / larger target / PRM configs for the synthetic task."""
    draft = ModelConfig(
        name="sx-draft", family="dense", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=192, vocab_size=vocab, head_dim=16,
        dtype="float32", param_dtype="float32")
    target = dataclasses.replace(draft, name="sx-target", num_layers=4,
                                 d_model=160, head_dim=40, d_ff=448)
    prm = dataclasses.replace(target, name="sx-prm", reward_head=True)
    return draft, target, prm


def train_triple(task, draft_cfg, target_cfg, prm_cfg, *, steps_draft=200,
                 steps_target=600, batch=32, seq=64, seed=0):
    """Target trained longer => genuinely stronger than the draft."""
    tc = TrainConfig(learning_rate=1e-3, total_steps=steps_target,
                     warmup_steps=20, seed=seed)
    tr_s = Trainer(draft_cfg, dataclasses.replace(tc,
                                                  total_steps=steps_draft))
    tr_s.fit((task.lm_batch(batch, seq) for _ in iter(int, 1)), steps_draft)
    tr_b = Trainer(target_cfg, tc)
    tr_b.fit((task.lm_batch(batch, seq) for _ in iter(int, 1)), steps_target)
    tr_p = Trainer(prm_cfg, tc, prm=True)
    tr_p.fit((task.prm_batch(batch, seq) for _ in iter(int, 1)),
             steps_target)
    return tr_s.params, tr_b.params, tr_p.params


def evaluate(engine, task, problems, rng):
    """Fixed-batch evaluation through ``engine.run`` (one gang)."""
    Lp = max(len(p.prompt) for p in problems)
    prompts = np.zeros((len(problems), Lp), np.int32)
    for i, p in enumerate(problems):
        prompts[i, :len(p.prompt)] = p.prompt
    t0 = time.time()
    responses, stats = engine.run(prompts, rng)
    wall = time.time() - t0
    correct = 0
    for prob, steps in zip(problems, responses):
        flat = [t for s in steps for t in s]
        correct += task.is_correct(prob, flat)
    return {"accuracy": correct / len(problems),
            "accept_rate": stats.accept_rate, "steps": stats.steps,
            "wall_s": wall, "stats": stats}


def make_frontend(engines, *, capacity: int, continuous: bool = True,
                  collect_stats: bool = False, policy: str = "affinity",
                  sync: bool = True, hash_tier: str = "mod",
                  chunk_tokens: int = 0):
    """One serving frontend over one or many engines.

    A single engine (or a 1-list) gets a plain :class:`GSIScheduler`;
    a list of N > 1 engines gets a :class:`ReplicaRouter` fronting N
    replicas of ``capacity`` slots each, routed by ``policy`` (tier-2
    preamble hashing per ``hash_tier``).  ``sync=False`` selects the
    pipelined decode loop (and, for routers, the thread-per-replica
    fleet loop); ``chunk_tokens`` meters prompt prefill (chunked
    prefill, 0 = unmetered).  Both frontends expose the same
    submit()/run()/stats/prefix_stats()/pipeline_stats() surface.
    """
    if isinstance(engines, GSIServingEngine):
        engines = [engines]
    if len(engines) == 1:
        return GSIScheduler(engines[0], capacity=capacity,
                            continuous=continuous,
                            collect_stats=collect_stats, sync=sync,
                            chunk_tokens=chunk_tokens)
    return ReplicaRouter(engines, capacity=capacity, policy=policy,
                         continuous=continuous,
                         collect_stats=collect_stats, sync=sync,
                         threaded=not sync, hash_tier=hash_tier,
                         chunk_tokens=chunk_tokens)


def _frontend_schedulers(sched):
    """The per-engine schedulers behind a frontend (router or single)."""
    if isinstance(sched, ReplicaRouter):
        return [rep.scheduler for rep in sched.replicas]
    return [sched]


def load_frontend_cache(sched, cache_dir: str) -> int:
    """Warm-restart a frontend from ``cache_dir`` snapshots.

    Loads ``cache-r{i}.npz`` (written by :func:`save_frontend_cache`)
    into replica ``i``'s state through the engine's snapshot codec —
    restored radix subtrees serve their first requests from spliced KV
    pages instead of a cold prefill.  Missing files are skipped (a
    replica added since the last save simply starts cold).  Returns the
    number of replicas restored.
    """
    loaded = 0
    for i, s in enumerate(_frontend_schedulers(sched)):
        path = os.path.join(cache_dir, f"cache-r{i}.npz")
        if not os.path.exists(path):
            continue
        s.state = s.engine.load_cache(s.state, path)
        loaded += 1
    return loaded


def save_frontend_cache(sched, cache_dir: str) -> int:
    """Persist every replica's hot radix cache to ``cache_dir``.

    One ``cache-r{i}.npz`` per replica (engines without a live prefix
    cache are skipped).  Returns the number of snapshots written.
    """
    os.makedirs(cache_dir, exist_ok=True)
    saved = 0
    for i, s in enumerate(_frontend_schedulers(sched)):
        eng = s.engine
        if not getattr(eng, "paged", False) or not eng.prefix_cache:
            continue
        eng.save_cache(s.state, os.path.join(cache_dir, f"cache-r{i}.npz"))
        saved += 1
    return saved


def evaluate_queued(engine, task, problems, rng, *, capacity: int,
                    continuous: bool = True, policy: str = "affinity",
                    sync: bool = True, hash_tier: str = "mod",
                    chunk_tokens: int = 0, priority_every: int = 0,
                    deadline_s=None, stream=None, cache_dir: str = ""):
    """Queued evaluation through the continuous-batching scheduler.

    All requests are submitted up front (offered load >= capacity); the
    scheduler packs them onto ``capacity`` slots, re-admitting queued
    prompts into freed slots.  ``engine`` may also be a list of engines —
    one per data-parallel replica, fronted by a :class:`ReplicaRouter`
    with ``policy`` placement.  ``sync=False`` serves through the async
    pipeline (identical tokens, overlapped host work).

    ``priority_every=k`` submits every k-th request at priority 1 (with
    ``deadline_s`` as its SLO), arming preemption; ``stream`` attaches a
    token-stream callback to the first request.  ``cache_dir`` enables
    warm restarts: per-replica radix-cache snapshots are loaded from it
    before serving (if present) and saved back after the run.  Returns
    accuracy plus throughput/latency.
    """
    sched = make_frontend(engine, capacity=capacity, continuous=continuous,
                          collect_stats=True, policy=policy, sync=sync,
                          hash_tier=hash_tier, chunk_tokens=chunk_tokens)
    if cache_dir:
        warm = load_frontend_cache(sched, cache_dir)
        print(f"cache-dir {cache_dir}: restored {warm} replica "
              f"snapshot(s)", flush=True)
    ids = []
    for i, p in enumerate(problems):
        hi = bool(priority_every) and i % priority_every == 0
        ids.append(sched.submit(np.asarray(p.prompt, np.int32),
                                priority=1 if hi else 0,
                                deadline_s=deadline_s if hi else None,
                                stream=stream if i == 0 else None))
    t0 = time.time()
    results = sched.run(rng)
    wall = time.time() - t0
    if cache_dir:
        saved = save_frontend_cache(sched, cache_dir)
        print(f"cache-dir {cache_dir}: saved {saved} replica "
              f"snapshot(s)", flush=True)
    correct, tokens = 0, 0
    latencies = []
    for prob, rid in zip(problems, ids):
        resp = results[rid]
        correct += task.is_correct(prob, list(resp.tokens))
        tokens += resp.num_tokens
        latencies.append(resp.latency)
    lat = np.sort(np.asarray(latencies)) if latencies else np.zeros(1)
    ttft = [results[r].ttft for r in ids
            if not np.isnan(results[r].ttft)]
    return {"accuracy": correct / len(problems),
            "accept_rate": sched.stats.accept_rate,
            "steps": sched.engine_steps, "wall_s": wall,
            "tokens": tokens, "tokens_per_s": tokens / max(wall, 1e-9),
            "latency_p50": float(np.percentile(lat, 50)),
            "latency_p95": float(np.percentile(lat, 95)),
            "ttft_p50": float(np.percentile(ttft, 50)) if ttft else 0.0,
            "preemptions": sched.stats.preemptions,
            "deadline_misses": sched.stats.deadline_misses,
            "prefill_commit_max": sched.stats.prefill_commit_max,
            "prefix": sched.prefix_stats(),
            "pipeline": sched.pipeline_stats(),
            "stats": sched.stats, "responses": results}


def main() -> None:
    """CLI entry point (see module docstring for usage)."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--n", type=int, default=4)
    ap.add_argument("--method", default="gsi",
                    choices=["gsi", "gsi_norej", "rsd", "sbon_s", "sbon_b"])
    ap.add_argument("--beta", type=float, default=20.0)
    ap.add_argument("--u", type=float, default=0.5)
    ap.add_argument("--train-steps", type=int, default=400)
    ap.add_argument("--capacity", type=int, default=0,
                    help="scheduler slots (0 = half the request count)")
    ap.add_argument("--gang", action="store_true",
                    help="fixed-batch gang scheduling instead of "
                         "continuous batching")
    ap.add_argument("--paged", action="store_true",
                    help="paged KV-cache (page pools + copy-on-write "
                         "candidate branching) instead of dense rows")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--num-pages", type=int, default=0,
                    help="page pool size (0 = dense-equivalent capacity)")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable the radix prefix cache (cross-request "
                         "KV sharing; on by default for --paged)")
    ap.add_argument("--kv-dtype", default="fp",
                    choices=["fp", "bf16", "int8", "fp8"],
                    help="paged KV-page storage format (requires --paged): "
                         "fp keeps the activation dtype; int8/fp8 store "
                         "quantized codes with per-page scales, dequant "
                         "fused into the paged-attention kernel")
    ap.add_argument("--quantize-draft", action="store_true",
                    help="round the draft model's matmul weights through "
                         "int8 (per-channel scales) at engine load")
    ap.add_argument("--replicas", type=int, default=1,
                    help="data-parallel serving replicas (each gets its "
                         "own engine, page pool and radix index; "
                         "capacity is per replica)")
    ap.add_argument("--mesh-shape", default="", metavar="DxM",
                    help="per-replica device submesh shape as "
                         "data x model (e.g. 1x2 = 2-way tensor "
                         "parallelism); carves the visible devices into "
                         "one disjoint submesh per replica and shards "
                         "each target model over its 'model' axis")
    ap.add_argument("--tp", type=int, default=0, metavar="N",
                    help="shorthand for --mesh-shape 1xN (N-way tensor "
                         "parallelism per replica)")
    ap.add_argument("--router", default="affinity", choices=list(POLICIES),
                    help="replica placement policy (preamble-affinity "
                         "keeps shared-prefix requests on one replica)")
    ap.add_argument("--hash-tier", default="mod", choices=list(HASH_TIERS),
                    help="affinity tier-2 preamble hash: mod (blake2b "
                         "mod N) or rendezvous (adding a replica remaps "
                         "only ~1/N of preamble groups)")
    grp = ap.add_mutually_exclusive_group()
    grp.add_argument("--async", dest="sync", action="store_false",
                     help="pipelined serving (default): one step ticket "
                          "in flight, harvest/admission overlap device "
                          "decode; thread-per-replica fleet loop")
    grp.add_argument("--sync", dest="sync", action="store_true",
                     help="lock-step serving loop (identical tokens)")
    ap.set_defaults(sync=False)
    ap.add_argument("--chunk-tokens", type=int, default=0,
                    help="per-step prefill token budget (chunked "
                         "prefill; 0 = admit whole prompts at once)")
    ap.add_argument("--priority", type=int, default=0, metavar="K",
                    help="submit every K-th request at priority 1 "
                         "(arms preemption of priority-0 slots under "
                         "pressure; 0 = uniform priority)")
    ap.add_argument("--deadline", type=float, default=0.0,
                    help="SLO deadline (seconds, arrival->finish) "
                         "attached to the priority-1 requests")
    ap.add_argument("--stream", action="store_true",
                    help="print the first request's tokens as they are "
                         "harvested (per-step streaming callback)")
    ap.add_argument("--cache-dir", default="",
                    help="warm-restart directory: per-replica radix "
                         "cache snapshots (cache-rN.npz) are restored "
                         "from here before serving and saved back after "
                         "(requires --paged with the prefix cache on)")
    ap.add_argument("--tuned-env", action="store_true",
                    help="apply the XLA/allocator env tuning "
                         "(XLA_FLAGS step markers + single host device, "
                         "bounded client mem fraction) before serving "
                         "and print what was applied")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.tuned_env:
        applied = apply_tuned_env()
        for key in sorted(TUNED_ENV):
            mark = "applied" if key in applied else "kept"
            print(f"tuned-env [{mark}] {key}={os.environ[key]}",
                  flush=True)

    task = SyntheticReasoningTask(seed=args.seed)
    draft_cfg, target_cfg, prm_cfg = toy_triple()
    print("training draft/target/PRM triple ...", flush=True)
    ps, pb, pp = train_triple(task, draft_cfg, target_cfg, prm_cfg,
                              steps_draft=args.train_steps // 2,
                              steps_target=args.train_steps, seed=args.seed)

    g = GSIConfig(n=args.n, beta=args.beta, threshold_u=args.u,
                  max_step_tokens=8, max_steps=8)
    capacity = args.capacity or max(1, args.requests // 2)
    if args.replicas > 1:
        # per-replica capacity so --replicas scales the fleet, not the
        # footprint of each engine
        capacity = max(1, capacity // args.replicas)
    kv_dtype = None if args.kv_dtype == "fp" else args.kv_dtype
    if args.mesh_shape and args.tp:
        raise SystemExit("use --mesh-shape or --tp, not both")
    mesh_shape = None
    if args.mesh_shape:
        mesh_shape = parse_mesh_shape(args.mesh_shape)
    elif args.tp > 1:
        mesh_shape = (1, args.tp)
    submeshes = [None] * args.replicas
    if mesh_shape is not None:
        submeshes = carve_submeshes(args.replicas, mesh_shape)
        print(f"mesh: {args.replicas} replica(s) x "
              f"{mesh_shape[0]}x{mesh_shape[1]} (data x model) submesh "
              f"over {len(jax.devices())} visible device(s)", flush=True)
    engines = [
        GSIServingEngine(draft_cfg, target_cfg, prm_cfg, ps, pb, pp, g,
                         mode=args.method, max_seq=128,
                         paged=args.paged, page_size=args.page_size,
                         num_pages=args.num_pages,
                         prefix_cache=not args.no_prefix_cache,
                         kv_dtype=kv_dtype,
                         quantize_draft=args.quantize_draft,
                         mesh=submeshes[i])
        for i in range(args.replicas)]
    engine = engines[0]
    problems = [task.sample_problem() for _ in range(args.requests)]

    def _print_stream(event):
        tag = f"[{event.finish_reason}]" if event.final \
            else " ".join(map(str, event.tokens.tolist()))
        print(f"stream {event.request_id} step {event.step}: {tag}",
              flush=True)

    res = evaluate_queued(engines if args.replicas > 1 else engine,
                          task, problems,
                          jax.random.PRNGKey(args.seed + 1),
                          capacity=capacity, continuous=not args.gang,
                          policy=args.router, sync=args.sync,
                          hash_tier=args.hash_tier,
                          chunk_tokens=args.chunk_tokens,
                          priority_every=args.priority,
                          deadline_s=args.deadline or None,
                          stream=_print_stream if args.stream else None,
                          cache_dir=args.cache_dir)
    if args.priority or args.chunk_tokens:
        print(f"slo: preemptions={res['preemptions']} "
              f"deadline_misses={res['deadline_misses']} "
              f"prefill_commit_max={res['prefill_commit_max']} "
              f"ttft_p50={res['ttft_p50']*1e3:.0f}ms", flush=True)
    if args.paged:
        rep = engine.cache_memory_report(capacity)
        print(f"paged cache [{rep['kv_dtype']}]: {rep['num_pages']} pages "
              f"x {rep['bytes_per_page']} B "
              f"(+{rep['scale_bytes_per_page']} B scales, "
              f"fp page {rep['fp_bytes_per_page']} B); "
              f"capacity {rep['capacity_tokens']} tokens / "
              f"{rep['capacity_bytes']>>10} KiB; branch scratch "
              f"{rep['paged_branch_bytes']>>10} KiB vs dense "
              f"{rep['dense_branch_bytes']>>10} KiB "
              f"({rep['branch_reduction']:.1f}x); "
              f"peak assigned {rep.get('pages_peak', 0)} pages")
        if rep["devices"] > 1:
            print(f"  sharded over {rep['devices']} devices: "
                  f"{rep['bytes_per_device']>>10} KiB/device "
                  f"({rep['capacity_tokens_per_device']} tokens/device "
                  f"at target-KV parity)")
        px = res["prefix"]
        print(f"prefix cache: hit_rate={px['hit_rate']:.2f} "
              f"prefill_tokens_skipped={px['hit_tokens']} "
              f"pages_reused={px['pages_reused']} "
              f"evicted={px['pages_evicted']} cached={px['pages_cached']}")
        if args.replicas > 1:
            for i, p in enumerate(px.get("per_replica", [])):
                print(f"  replica {i} ({args.router}): "
                      f"hit_rate={p['hit_rate']:.2f} "
                      f"({p['hits']}/{p['queries']} admissions) "
                      f"prefill_tokens={p['prefill_tokens']}")
    if not args.sync:
        pipe = res["pipeline"]
        print(f"async pipeline: overlap_fraction="
              f"{pipe['overlap_fraction']:.2f} "
              f"overlap_host={pipe['overlap_host_s']*1e3:.0f}ms "
              f"serial_host={pipe['serial_host_s']*1e3:.0f}ms "
              f"materialize_wait={pipe['materialize_wait_s']*1e3:.0f}ms")
    print(f"method={args.method} n={args.n} capacity={capacity} "
          f"({'async' if not args.sync else 'sync'}, "
          f"{'gang' if args.gang else 'continuous'}"
          f"{', paged' if args.paged else ''}"
          f"{f', {args.replicas} replicas/{args.router}' if args.replicas > 1 else ''}): "
          f"accuracy={res['accuracy']:.3f} "
          f"accept={res['accept_rate']:.2f} steps={res['steps']} "
          f"wall={res['wall_s']:.1f}s tokens/s={res['tokens_per_s']:.1f} "
          f"p50={res['latency_p50']*1e3:.0f}ms "
          f"p95={res['latency_p95']*1e3:.0f}ms")

    lm = LatencyModel(
        ModelCost(draft_cfg.param_count(), 1024),
        ModelCost(target_cfg.param_count(), 4096),
        ModelCost(prm_cfg.param_count(), 4096), HW_V5E)
    t = lm.step_time(method=args.method, n=args.n, step_len=6, ctx_len=64,
                     accept_rate=res["accept_rate"])
    print(f"latency-model seconds/step on {HW_V5E.name}: {t:.2e}")


if __name__ == "__main__":
    main()
