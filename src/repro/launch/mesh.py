"""Production meshes + per-replica submesh carving.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state).  The dry-run entry point sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import so 512 placeholder devices exist; smoke tests and benchmarks see the
single real CPU device.

``carve_submeshes`` is the serving fleet's device partitioner: N disjoint
``(data, model)`` submeshes, one per router replica, all driven by the
thread-per-replica fleet loop in one process.  The multi-host variant
(one OS process per replica joined via ``jax.distributed.initialize``)
shares the interface but is stubbed — see
:func:`distributed_replica_mesh`.
"""
from __future__ import annotations

from typing import List

import jax
import numpy as np

from repro.config import MULTI_POD, SINGLE_POD, MeshConfig


def make_production_mesh(*, multi_pod: bool = False):
    """The 256-chip single-pod (or 512-chip two-pod) production mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_config(*, multi_pod: bool = False) -> MeshConfig:
    """The :class:`MeshConfig` matching :func:`make_production_mesh`."""
    return MULTI_POD if multi_pod else SINGLE_POD


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh over however many host devices exist (tests)."""
    return jax.make_mesh(shape, axes)


def carve_submeshes(num_replicas: int, shape=(1, 2),
                    axes=("data", "model"), devices=None) -> List:
    """Carve the process's devices into per-replica serving submeshes.

    Returns ``num_replicas`` disjoint ``jax.sharding.Mesh`` objects of
    ``shape`` over ``axes``, slicing ``devices`` (default
    ``jax.devices()``) in order — replica r owns devices
    ``[r*k, (r+1)*k)`` with ``k = prod(shape)``.  Disjointness is what
    lets the thread-per-replica fleet loop drive them concurrently:
    replicas share no device, so their collectives never interleave.
    Raises ``ValueError`` when the host has too few devices.
    """
    devices = list(jax.devices()) if devices is None else list(devices)
    k = int(np.prod(shape))
    need = num_replicas * k
    if len(devices) < need:
        raise ValueError(
            f"carve_submeshes: need {need} devices ({num_replicas} "
            f"replicas x {shape}), have {len(devices)}.  Force host "
            "devices with XLA_FLAGS=--xla_force_host_platform_device_"
            "count=N or lower --replicas/--mesh-shape.")
    return [
        jax.sharding.Mesh(
            np.asarray(devices[r * k:(r + 1) * k]).reshape(shape), axes)
        for r in range(num_replicas)
    ]


def distributed_replica_mesh(replica_index: int, num_replicas: int,
                             shape=(1, 2), axes=("data", "model"),
                             coordinator: str = "localhost:1234"):
    """Process-per-replica fleet over ``jax.distributed`` (stub).

    The multi-host deployment runs one OS process per replica: each
    process calls ``jax.distributed.initialize(coordinator,
    num_processes=num_replicas, process_id=replica_index)``, builds its
    replica's mesh from ``jax.local_devices()`` with exactly the layout
    :func:`carve_submeshes` uses in-process, and fronts it with the same
    ``ReplicaRouter`` — the rendezvous hash tier keeps fleet resizes at
    ~1/(N+1) moved preamble groups either way, so scale-out economics
    are identical.  The engine/scheduler/router code is already
    process-agnostic (replicas share no state but the router ledger,
    which becomes an RPC service here); what's missing is only the
    cross-process response/submit transport, so this entry point raises
    until that lands.
    """
    raise NotImplementedError(
        "process-per-replica serving over jax.distributed is documented "
        "but not wired yet: run the thread-per-replica fleet over "
        "carve_submeshes() instead (launch.serve --mesh-shape/--tp).")
