"""Production meshes.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state).  The dry-run entry point sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import so 512 placeholder devices exist; smoke tests and benchmarks see the
single real CPU device.
"""
from __future__ import annotations

import jax

from repro.config import MULTI_POD, SINGLE_POD, MeshConfig


def make_production_mesh(*, multi_pod: bool = False):
    """The 256-chip single-pod (or 512-chip two-pod) production mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_config(*, multi_pod: bool = False) -> MeshConfig:
    """The :class:`MeshConfig` matching :func:`make_production_mesh`."""
    return MULTI_POD if multi_pod else SINGLE_POD


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh over however many host devices exist (tests)."""
    return jax.make_mesh(shape, axes)
