"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combo.

For each combination this builds the real jitted program (train_step /
prefill / serve_step) with production shardings, lowers it against
ShapeDtypeStruct inputs (no allocation), compiles it for the 256-chip
single-pod and 512-chip two-pod meshes, and records memory analysis,
cost analysis and the roofline terms (repro.roofline).

    PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
        --out results/dryrun.json

NOTE: the XLA_FLAGS line below must run before ANY jax import (jax locks
the device count on first init); do not import this module from processes
that need the single real CPU device.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# chunked-parallel WKV for lowering (see models/rwkv.py::_use_chunked):
# the per-token sequential scan is exact but compiles pathologically when
# layers are unrolled, and XLA cost-analysis can't see through its loop.
os.environ.setdefault("REPRO_RWKV_CHUNKED", "1")

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import (SHAPES, TrainConfig, get_config, ModelConfig)
from repro.configs import ASSIGNED
from repro.distributed import context as dctx
from repro.distributed.sharding import (as_shardings, batch_pspec,
                                        cache_pspecs, param_pspecs)
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.optim import AdamW
from repro.roofline import roofline_terms
from repro.roofline.analysis import model_flops_estimate
from repro.train import make_train_step

LONG_WINDOW = 4096  # sliding-window variant for full-attention archs


def is_native_subquadratic(cfg: ModelConfig) -> bool:
    """True if the arch scales sub-quadratically in context natively."""
    return cfg.family in ("ssm", "hybrid") or "local" in cfg.layer_pattern


def arch_for_shape(cfg: ModelConfig, shape_name: str,
                   *, scan_layers: bool = False) -> ModelConfig:
    """Shape-specific config transform applied before lowering."""
    if shape_name == "long_500k" and not is_native_subquadratic(cfg):
        # DESIGN.md §4: dense/full-attention archs serve long context with
        # the sliding-window variant (ring KV cache of LONG_WINDOW).
        cfg = dataclasses.replace(cfg, serve_window_override=LONG_WINDOW)
    # Unroll layers for the dry-run: XLA's cost_analysis counts while-loop
    # bodies once (verified), so scanned stacks would under-report the
    # roofline terms by ~num_layers x.  Production training keeps the scan.
    if not scan_layers:
        cfg = dataclasses.replace(cfg, scan_layers=False)
    return cfg


def _source_shape(cfg: ModelConfig, batch: int):
    if cfg.encoder_layers:
        return jax.ShapeDtypeStruct((batch, cfg.encoder_seq, cfg.d_model),
                                    jnp.bfloat16)
    if cfg.cross_source_seq:
        return jax.ShapeDtypeStruct((batch, cfg.cross_source_seq,
                                     cfg.d_model), jnp.bfloat16)
    return None


def build_lowerable(cfg: ModelConfig, shape_name: str, mesh,
                    *, transform: bool = True):
    """Returns (fn, args, in_shardings, model_flops)."""
    shape = SHAPES[shape_name]
    if transform:
        cfg = arch_for_shape(cfg, shape_name)
    model = build_model(cfg)
    spec_tree = model.param_specs()
    B, S = shape.global_batch, shape.seq_len
    bspec = batch_pspec(mesh, B)
    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    src = _source_shape(cfg, B)

    if shape.kind == "train":
        mode = "train"
        p_sh = as_shardings(param_pspecs(spec_tree, mesh, mode), mesh)
        big = cfg.param_count() > 3e11
        tcfg = TrainConfig(opt_state_dtype="bfloat16" if big else "float32")
        opt = AdamW(tcfg)
        opt_shape = jax.eval_shape(opt.init, params_shape)
        opt_sh = {"m": p_sh, "v": p_sh,
                  "count": NamedSharding(mesh, P())}
        batch_shape = {
            "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "loss_mask": jax.ShapeDtypeStruct((B, S), jnp.float32),
        }
        batch_sh = {k: NamedSharding(mesh, P(bspec, None))
                    for k in batch_shape}
        if src is not None:
            batch_shape["source"] = src
            batch_sh["source"] = NamedSharding(mesh, P(bspec, None, None))
        fn = make_train_step(cfg, tcfg, with_source=src is not None)
        args = (params_shape, opt_shape, batch_shape)
        shardings = (p_sh, opt_sh, batch_sh)
        mflops = model_flops_estimate(cfg, B * S, "train") / mesh.devices.size

    elif shape.kind == "prefill":
        mode = "serve"
        p_sh = as_shardings(param_pspecs(spec_tree, mesh, mode), mesh)
        toks = jax.ShapeDtypeStruct((B, S), jnp.int32)
        tok_sh = NamedSharding(mesh, P(bspec, None))
        if src is not None:
            def fn(params, tokens, source):
                return model.prefill(params, tokens, source=source,
                                     max_seq=S)
            args = (params_shape, toks, src)
            shardings = (p_sh, tok_sh,
                         NamedSharding(mesh, P(bspec, None, None)))
        else:
            def fn(params, tokens):
                return model.prefill(params, tokens, max_seq=S)
            args = (params_shape, toks)
            shardings = (p_sh, tok_sh)
        mflops = model_flops_estimate(cfg, B * S, "prefill") / mesh.devices.size

    else:  # decode
        mode = "serve"
        p_sh = as_shardings(param_pspecs(spec_tree, mesh, mode), mesh)
        cache_shape = jax.eval_shape(lambda: model.init_cache(B, S))
        cache_sh = as_shardings(cache_pspecs(cache_shape, mesh), mesh)
        toks = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        pos = jax.ShapeDtypeStruct((B,), jnp.int32)
        vec_sh = NamedSharding(mesh, P(bspec))

        def fn(params, cache, tokens, positions):
            return model.decode_step(params, cache, tokens, positions)

        args = (params_shape, cache_shape, toks, pos)
        shardings = (p_sh, cache_sh, NamedSharding(mesh, P(bspec, None)),
                     vec_sh)
        mflops = model_flops_estimate(cfg, B, "decode") / mesh.devices.size

    return fn, args, shardings, mflops


def _compile_record(cfg, shape_name, mesh, chips, name, *,
                    transform: bool = True):
    fn, args, shardings, mflops = build_lowerable(cfg, shape_name, mesh,
                                                  transform=transform)
    t0 = time.time()
    lowered = jax.jit(fn, in_shardings=shardings).lower(*args)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    text = compiled.as_text()
    rep = roofline_terms(name, compiled, chips=chips, model_flops=mflops,
                         hlo_text=text)
    return rep, mem, t_lower, t_compile


def run_one(arch: str, shape_name: str, mesh_kind: str,
            *, keep_hlo: bool = False) -> dict:
    """Lower + compile one (arch, shape, mesh) combo; returns the record."""
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = mesh.devices.size
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "chips": chips, "status": "error"}
    t0 = time.time()
    name = f"{arch}/{shape_name}/{mesh_kind}"
    seq_heavy = SHAPES[shape_name].kind in ("train", "prefill")
    try:
        with dctx.use_mesh(mesh):
            if cfg.family == "ssm" and seq_heavy:
                # Two-point accounting: the WKV sequence work makes the
                # unrolled stack pathological to compile, so compile the
                # scanned stack with 1-layer and 2-layer scan bodies and
                # extrapolate the exact per-device costs
                # (cost_analysis counts scan bodies once):
                #   F(total) = F1 + (num_layers - 1) * (F2 - F1).
                os.environ["REPRO_RWKV_CHUNK"] = str(
                    max(256, SHAPES[shape_name].seq_len // 16))
                cfg1 = dataclasses.replace(cfg, scan_layers=True)
                cfg2 = dataclasses.replace(cfg, scan_layers=True,
                                           layer_pattern=("full", "full"))
                rep1, mem, tl, tc = _compile_record(
                    arch_for_shape(cfg1, shape_name, scan_layers=True),
                    shape_name, mesh, chips, name, transform=False)
                rep2, _, tl2, tc2 = _compile_record(
                    arch_for_shape(cfg2, shape_name, scan_layers=True),
                    shape_name, mesh, chips, name, transform=False)
                L = cfg.num_layers
                rep = rep1
                rep.flops = rep1.flops + (L - 1) * (rep2.flops - rep1.flops)
                rep.bytes_accessed = rep1.bytes_accessed + (L - 1) * (
                    rep2.bytes_accessed - rep1.bytes_accessed)
                # collective bytes: the HLO parser already multiplies scan
                # bodies by known_trip_count; rep1 is the full program.
                t_lower, t_compile = tl + tl2, tc + tc2
                rec["accounting"] = "ssm-two-point"
            else:
                rep, mem, t_lower, t_compile = _compile_record(
                    arch_for_shape(cfg, shape_name), shape_name, mesh,
                    chips, name)
        rec.update(rep.as_dict())
        rec.update(
            status="ok", lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            bytes_per_device=getattr(mem, "temp_size_in_bytes", 0)
            + getattr(mem, "argument_size_in_bytes", 0),
            temp_bytes=getattr(mem, "temp_size_in_bytes", 0),
            arg_bytes=getattr(mem, "argument_size_in_bytes", 0),
            output_bytes=getattr(mem, "output_size_in_bytes", 0),
            peak_bytes=getattr(mem, "peak_memory_in_bytes", 0),
        )
        if keep_hlo:
            rec["hlo_len"] = len(text)
    except Exception as e:  # noqa: BLE001 — record and continue
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["elapsed_s"] = round(time.time() - t0, 1)
    return rec


def main() -> None:
    """CLI entry point (see module docstring for usage)."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    archs = list(ASSIGNED) if args.all or args.arch is None else [args.arch]
    shapes = list(SHAPES) if args.all or args.shape is None else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    results = {}
    if args.out and os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    for arch in archs:
        for shape in shapes:
            for mk in meshes:
                key = f"{arch}|{shape}|{mk}"
                if results.get(key, {}).get("status") == "ok":
                    print(f"[skip] {key}", flush=True)
                    continue
                print(f"[run ] {key}", flush=True)
                rec = run_one(arch, shape, mk)
                results[key] = rec
                if rec["status"] == "ok":
                    print(f"  ok  compile={rec['compile_s']}s "
                          f"flops={rec['hlo_flops']:.3e} "
                          f"coll={rec['collective_bytes']:.3e}B "
                          f"dom={rec['dominant']} "
                          f"mem/dev={rec['peak_bytes']/2**30:.2f}GiB",
                          flush=True)
                else:
                    print(f"  ERR {rec['error']}", flush=True)
                if args.out:
                    os.makedirs(os.path.dirname(os.path.abspath(args.out)),
                                exist_ok=True)
                    with open(args.out, "w") as f:
                        json.dump(results, f, indent=1)
    bad = [k for k, v in results.items() if v.get("status") != "ok"]
    print(f"\n{len(results) - len(bad)}/{len(results)} OK; failures: {bad}")


if __name__ == "__main__":
    main()
