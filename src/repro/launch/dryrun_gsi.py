"""Dry-run of the GSI serving phases at paper scale (hillclimb target #3).

Lowers the *target-scoring* pass of Algorithm 1 — compute log pi_B(y_i|x)
for n draft candidate steps against a committed context — for the paper's
Qwen2.5-Math-7B target on the production mesh, in two implementations:

  baseline  — the paper-faithful n-copy scoring: the committed KV cache is
              repeated n times and candidates are teacher-forced through
              decode steps (a scan over L tokens).
  shared    — beyond-paper shared-prefix scoring (models/scoring.py): all n
              candidates attend to ONE shared cache; no copies, no scan.

Also lowers the fused "tilted select" epilogue (rewards + logp -> softmax
sample + threshold), which is negligible but completes Algorithm 1.

    PYTHONPATH=src python -m repro.launch.dryrun_gsi --out results/gsi.json

NOTE: the XLA_FLAGS line below must run before ANY jax import (jax locks
the device count on first init).
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import get_config
from repro.distributed import context as dctx
from repro.distributed.sharding import (as_shardings, batch_pspec,
                                        cache_pspecs, param_pspecs)
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.models.scoring import score_candidates
from repro.roofline import roofline_terms

# serving shape: 8 requests/pod-slice, n=16 candidates, 128-token steps,
# 2048-token committed context (paper: ~220-token steps, ~10 steps)
B, N, L, CTX = 16, 16, 128, 2048


def build(kind: str, mesh, arch: str = "qwen2.5-math-7b",
          scan_layers: bool = True):
    """Build the lowerable scoring fn for ``kind`` (baseline|shared|select).

    Returns ``(fn, args, in_shardings, cfg)`` ready for jit + lower.
    """
    cfg = dataclasses.replace(get_config(arch), scan_layers=scan_layers)
    model = build_model(cfg)
    spec_tree = model.param_specs()
    p_sh = as_shardings(param_pspecs(spec_tree, mesh, "serve"), mesh)
    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    cache_shape = jax.eval_shape(lambda: model.init_cache(B, CTX + 2 * L))
    cache_sh = as_shardings(cache_pspecs(cache_shape, mesh), mesh)
    bspec = batch_pspec(mesh, B)
    pend = jax.ShapeDtypeStruct((B,), jnp.int32)
    pos = jax.ShapeDtypeStruct((B,), jnp.int32)
    vec_sh = NamedSharding(mesh, P(bspec))

    if kind == "shared":
        cands = jax.ShapeDtypeStruct((B, N, L), jnp.int32)
        # shared scoring keeps the request dim at B (not B*N): when B is
        # smaller than the (pod x data) batch ways, shard the candidate dim
        # over 'pod' so the multi-pod mesh still parallelizes the pass.
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        cand_spec = P(bspec, None, None)
        if "pod" in sizes and bspec == "data" and N % sizes["pod"] == 0:
            cand_spec = P("data", "pod", None)

        def fn(params, cache, pending, positions, cand):
            return score_candidates(model, params, cache, pending,
                                    positions, cand)

        args = (params_shape, cache_shape, pend, pos, cands)
        sh = (p_sh, cache_sh, vec_sh, vec_sh,
              NamedSharding(mesh, cand_spec))
    else:
        # baseline (paper-faithful): each candidate scores against its OWN
        # copy of the committed cache.  Expressed as the same scoring
        # program with an N-times repeated cache and per-row candidates, so
        # the HLO accounting isolates exactly the shared-prefix saving
        # (identical FLOPs; cache bytes/collectives scale by N).
        from repro.serving.engine import expand_requests, repeat_cache
        cands = jax.ShapeDtypeStruct((B * N, 1, L), jnp.int32)
        big_cache_shape = jax.eval_shape(
            lambda c: repeat_cache(c, N), cache_shape)
        big_cache_sh = as_shardings(cache_pspecs(big_cache_shape, mesh),
                                    mesh)
        pend_n = jax.ShapeDtypeStruct((B * N,), jnp.int32)
        bspec_n = batch_pspec(mesh, B * N)
        vec_n = NamedSharding(mesh, P(bspec_n))

        def fn(params, cache, pending, positions, cand):
            lp = score_candidates(model, params, cache, pending,
                                  positions, cand)
            return lp.reshape(B, N)

        args = (params_shape, big_cache_shape, pend_n, pend_n, cands)
        sh = (p_sh, big_cache_sh, vec_n, vec_n,
              NamedSharding(mesh, P(bspec_n, None, None)))
    return fn, args, sh, cfg


def run_one(kind: str, mesh_kind: str = "single") -> dict:
    """Lower + compile one scoring kind; returns its analysis record."""
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rec = {"kind": kind, "mesh": mesh_kind, "status": "error"}
    t0 = time.time()
    try:
        with dctx.use_mesh(mesh):
            fn, args, sh, cfg = build(kind, mesh)
            compiled = jax.jit(fn, in_shardings=sh).lower(*args).compile()
            mem = compiled.memory_analysis()
            rep = roofline_terms(f"gsi-score-{kind}", compiled,
                                 chips=mesh.devices.size,
                                 model_flops=2.0 * cfg.param_count() * B * N
                                 * L / mesh.devices.size)
        rec.update(rep.as_dict())
        rec.update(status="ok",
                   peak_bytes=getattr(mem, "peak_memory_in_bytes", 0),
                   arg_bytes=getattr(mem, "argument_size_in_bytes", 0),
                   compile_s=round(time.time() - t0, 1))
    except Exception as e:  # noqa: BLE001
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-1500:]
    rec["elapsed_s"] = round(time.time() - t0, 1)
    return rec


def main() -> None:
    """CLI entry point (see module docstring for usage)."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/gsi_dryrun.json")
    ap.add_argument("--kinds", default="baseline,shared")
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    results = {}
    if os.path.exists(args.out):
        results = json.load(open(args.out))
    for kind in args.kinds.split(","):
        key = f"{kind}|{args.mesh}"
        if results.get(key, {}).get("status") == "ok":
            print(f"[skip] {key}")
            continue
        print(f"[run ] {key}", flush=True)
        rec = run_one(kind, args.mesh)
        results[key] = rec
        print(json.dumps({k: v for k, v in rec.items()
                          if k not in ("traceback",)}, default=str),
              flush=True)
        os.makedirs(os.path.dirname(os.path.abspath(args.out)),
                    exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
