"""Token-level speculative decoding (Leviathan et al., 2023) — baseline.

The paper argues step-level speculation (GSI) scales better with batch than
token-level SD; we include the token-level accept/reject rule so the claim
is testable in-framework.  Given k draft tokens with draft/target
probabilities, accept each token with prob min(1, p_B/p_S); on first
rejection resample from the residual distribution max(0, p_B - p_S).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class SpecDecodeResult(NamedTuple):
    num_accepted: jnp.ndarray   # (B,) tokens accepted (0..k)
    accept_mask: jnp.ndarray    # (B,k)
    resample_tok: jnp.ndarray   # (B,) token drawn from residual at rejection


def speculative_verify(rng, draft_tokens, logits_S, logits_B):
    """draft_tokens: (B,k); logits_*: (B,k,V) at each draft position.

    Exactness: the output sequence is distributed as target sampling.
    """
    B, k, V = logits_B.shape
    p_S = jax.nn.softmax(logits_S.astype(jnp.float32), -1)
    p_B = jax.nn.softmax(logits_B.astype(jnp.float32), -1)
    tok = draft_tokens[..., None]
    ps = jnp.take_along_axis(p_S, tok, -1)[..., 0]       # (B,k)
    pb = jnp.take_along_axis(p_B, tok, -1)[..., 0]
    k_acc, k_res = jax.random.split(rng)
    uni = jax.random.uniform(k_acc, (B, k))
    ok = uni < jnp.minimum(1.0, pb / jnp.clip(ps, 1e-20))
    # accepted prefix length = index of first rejection
    first_rej = jnp.argmin(jnp.concatenate(
        [ok, jnp.zeros((B, 1), bool)], 1), axis=1)       # k if none rejected
    accept_mask = jnp.arange(k)[None, :] < first_rej[:, None]
    # residual resample at the first rejected position
    pos = jnp.minimum(first_rej, k - 1)
    pb_pos = jnp.take_along_axis(p_B, pos[:, None, None].repeat(V, -1),
                                 1)[:, 0]
    ps_pos = jnp.take_along_axis(p_S, pos[:, None, None].repeat(V, -1),
                                 1)[:, 0]
    resid = jnp.clip(pb_pos - ps_pos, 0.0)
    resid = resid / jnp.clip(jnp.sum(resid, -1, keepdims=True), 1e-20)
    # fall back to target distribution if residual degenerate
    degenerate = jnp.sum(resid, -1) < 1e-6
    dist = jnp.where(degenerate[:, None], pb_pos, resid)
    resample = jax.random.categorical(k_res, jnp.log(jnp.clip(dist, 1e-20)))
    return SpecDecodeResult(first_rej, accept_mask, resample)
