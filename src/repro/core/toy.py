"""Categorical toy environment — *exact* validation of Theorems 1 & 2.

Y is a finite outcome set; pi_S, pi_B are explicit categoricals and r an
explicit reward vector, so the optimal tilted policy pi_{beta,B}, chi^2, CV
and every bound are in closed form while GSI itself is simulated exactly as
Algorithm 1 (vectorized over many trials).  This is how we check the KL and
golden-reward guarantees numerically (EXPERIMENTS.md §Paper-claims).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import theory
from repro.core.tilting import tilted_policy


class GSITrials(NamedTuple):
    outcomes: jnp.ndarray        # (T,) final outcome per trial (with rejection)
    outcomes_tilde: jnp.ndarray  # (T,) outcome of pi~_GSI (no rejection)
    accept: jnp.ndarray          # (T,) acceptance indicator


class ToyEnv:
    def __init__(self, m: int = 12, *, seed: int = 0, skew: float = 1.5,
                 reward_seed=None):
        rng = np.random.default_rng(seed)
        # draft is a smoothed/perturbed version of the base => finite chi^2
        logits_b = rng.normal(0, skew, m)
        logits_s = logits_b + rng.normal(0, skew / 2, m)
        self.pi_B = jnp.asarray(_softmax(logits_b), jnp.float32)
        self.pi_S = jnp.asarray(_softmax(0.7 * logits_s), jnp.float32)
        rr = np.random.default_rng(
            seed if reward_seed is None else reward_seed)
        self.r = jnp.asarray(rr.uniform(0, 1, m), jnp.float32)
        # golden reward: noisy monotone transform of r (r "approximates" r*)
        self.r_star = jnp.clip(
            self.r + rr.normal(0, 0.1, m).astype(np.float32), 0, 1)
        self.m = m

    # -- closed forms -------------------------------------------------------
    def tilted(self, beta: float):
        return tilted_policy(self.pi_B, self.r, beta)

    @property
    def chi2(self):
        return theory.chi2_divergence(self.pi_B, self.pi_S)

    def cv(self, beta: float):
        return theory.coefficient_of_variation(self.pi_B, self.r, beta)

    def expected_golden(self, policy):
        return jnp.sum(policy * self.r_star)

    # -- Algorithm 1, vectorized over trials --------------------------------
    def run_gsi(self, rng, *, n: int, beta: float, u: float,
                trials: int = 200_000, n_target: int = 0) -> GSITrials:
        """Algorithm 1; n_target > 0 decouples the resampling-side n
        (the paper's flagged future-work knob)."""
        k_draft, k_sel, k_base, k_bsel = jax.random.split(rng, 4)
        # draft candidates
        ys = jax.random.categorical(
            k_draft, jnp.log(self.pi_S)[None, :], shape=(trials, n))
        log_ratio = jnp.log(self.pi_B) - jnp.log(self.pi_S)
        r_t = self.r[ys] + log_ratio[ys] / beta              # (T,n)
        idx = jax.random.categorical(k_sel, beta * r_t, axis=-1)
        sel = jnp.take_along_axis(ys, idx[:, None], 1)[:, 0]
        sel_rt = jnp.take_along_axis(r_t, idx[:, None], 1)[:, 0]
        accept = sel_rt >= u
        # rejection branch: S-BoN with pi_B and raw rewards
        nb = n_target or n
        yb = jax.random.categorical(
            k_base, jnp.log(self.pi_B)[None, :], shape=(trials, nb))
        jdx = jax.random.categorical(k_bsel, beta * self.r[yb], axis=-1)
        selb = jnp.take_along_axis(yb, jdx[:, None], 1)[:, 0]
        final = jnp.where(accept, sel, selb)
        return GSITrials(final, sel, accept)

    def run_rsd(self, rng, *, n: int, beta: float, threshold: float,
                trials: int = 200_000):
        k_draft, k_sel, k_base, k_bsel = jax.random.split(rng, 4)
        ys = jax.random.categorical(
            k_draft, jnp.log(self.pi_S)[None, :], shape=(trials, n))
        r = self.r[ys]
        idx = jax.random.categorical(k_sel, beta * r, axis=-1)
        sel = jnp.take_along_axis(ys, idx[:, None], 1)[:, 0]
        sel_r = jnp.take_along_axis(r, idx[:, None], 1)[:, 0]
        accept = sel_r >= threshold
        yb = jax.random.categorical(
            k_base, jnp.log(self.pi_B)[None, :], shape=(trials, n))
        jdx = jax.random.categorical(k_bsel, beta * self.r[yb], axis=-1)
        selb = jnp.take_along_axis(yb, jdx[:, None], 1)[:, 0]
        return GSITrials(jnp.where(accept, sel, selb), sel, accept)

    def run_sbon(self, rng, *, n: int, beta: float, base: bool,
                 trials: int = 200_000):
        """Plain S-BoN with pi_B (base=True) or pi_S."""
        pi = self.pi_B if base else self.pi_S
        k1, k2 = jax.random.split(rng)
        ys = jax.random.categorical(k1, jnp.log(pi)[None, :],
                                    shape=(trials, n))
        idx = jax.random.categorical(k2, beta * self.r[ys], axis=-1)
        return jnp.take_along_axis(ys, idx[:, None], 1)[:, 0]

    # -- empirical distribution helpers -------------------------------------
    def histogram(self, outcomes):
        counts = jnp.bincount(outcomes, length=self.m)
        return counts / outcomes.shape[0]


def _softmax(x):
    e = np.exp(x - np.max(x))
    return e / e.sum()
