"""GSI per-step decision (Algorithm 1, lines 4-6).

Given n draft candidates with PRM rewards and both models' log-likelihoods:
compute tilted rewards, soft-BoN-sample the index, and accept iff the
selected tilted reward clears the threshold u.  The resampling fallback
(lines 8-12) is model-level and lives in ``repro.serving.gsi_engine``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.sbon import soft_bon_select
from repro.core.tilting import tilted_rewards


class GSIDecision(NamedTuple):
    index: jnp.ndarray        # (B,) selected candidate i*
    tilted: jnp.ndarray       # (B, n) tilted rewards r~
    selected_tilted: jnp.ndarray  # (B,) r~_{i*}
    accept: jnp.ndarray       # (B,) r~_{i*} >= u


def gsi_select(rng, rewards, logp_B, logp_S, *, beta: float,
               threshold_u: float) -> GSIDecision:
    """rewards/logp_B/logp_S: (B, n) per draft candidate."""
    r_t = tilted_rewards(rewards, logp_B, logp_S, beta)
    idx = soft_bon_select(rng, r_t, beta)
    sel = jnp.take_along_axis(r_t, idx[:, None], axis=-1)[:, 0]
    return GSIDecision(idx, r_t, sel, sel >= threshold_u)
