"""Reward-guided speculative decoding baseline (Liao et al., 2025).

Same step-level speculation skeleton as GSI but with *raw* PRM rewards (no
likelihood-ratio tilting) and the raw-reward acceptance threshold (0.7 in
their paper).  This is the paper's main baseline; its guarantee is only on
the expected reward, not the policy.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.sbon import soft_bon_select


class RSDDecision(NamedTuple):
    index: jnp.ndarray
    selected_reward: jnp.ndarray
    accept: jnp.ndarray


def rsd_select(rng, rewards, *, beta: float, threshold: float) -> RSDDecision:
    """rewards: (B, n) raw PRM rewards of the draft candidates."""
    idx = soft_bon_select(rng, rewards, beta)
    sel = jnp.take_along_axis(rewards.astype(jnp.float32), idx[:, None],
                              axis=-1)[:, 0]
    return RSDDecision(idx, sel, sel >= threshold)
