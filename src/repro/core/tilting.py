"""Reward tilting — the central identity of GSI (paper §4).

The optimal KL-regularized policy  pi_{beta,B}(y|x) ∝ pi_B(y|x) e^{beta r}
can be rewritten over the *draft* model:

    pi_{beta,B}(y|x) ∝ pi_S(y|x) exp(beta * r~(x,y)),
    r~(x,y) = r(x,y) + (1/beta) * log(pi_B(y|x) / pi_S(y|x)).

So soft best-of-n over draft samples with the *tilted* rewards r~
approximates pi_{beta,B} (Theorem 1).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tilted_rewards(r, logp_B, logp_S, beta: float):
    """r~ = r + (log pi_B - log pi_S) / beta  (elementwise)."""
    return (r.astype(jnp.float32)
            + (logp_B.astype(jnp.float32) - logp_S.astype(jnp.float32))
            / beta)


def tilted_policy(pi_B, r, beta: float):
    """Exact tilted categorical pi_{beta,B} ∝ pi_B * exp(beta r).

    pi_B: (..., m) probabilities; r: (..., m) rewards.
    """
    logp = jnp.log(jnp.clip(pi_B, 1e-38)) + beta * r
    return jax.nn.softmax(logp, axis=-1)


def log_partition(pi_B, r, beta: float):
    """log Z_{beta,B} = log E_{pi_B}[e^{beta r}]."""
    logp = jnp.log(jnp.clip(pi_B, 1e-38)) + beta * r
    return jax.scipy.special.logsumexp(logp, axis=-1)
