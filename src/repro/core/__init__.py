"""The paper's primary contribution: Guided Speculative Inference.

Array-level decision math lives here (model-free, reused by the toy
environment, the tests and the serving engine); the three-model serving
orchestration is ``repro.serving.gsi_engine``.
"""
from repro.core.sbon import soft_bon_select, hard_bon_select  # noqa: F401
from repro.core.tilting import (  # noqa: F401
    tilted_rewards, tilted_policy, log_partition)
from repro.core.gsi import gsi_select, GSIDecision  # noqa: F401
from repro.core.rsd import rsd_select  # noqa: F401
from repro.core import theory  # noqa: F401
from repro.core.toy import ToyEnv  # noqa: F401
