"""(Soft) best-of-n selection (Verdun et al., 2025; Beirami et al., 2025)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def soft_bon_select(rng, rewards, beta: float):
    """Sample index i ~ softmax(beta * rewards) per row.

    rewards: (..., n) -> indices (...,).  beta -> inf recovers hard BoN,
    beta -> 0 uniform choice.
    """
    logits = beta * rewards.astype(jnp.float32)
    return jax.random.categorical(rng, logits, axis=-1)


def hard_bon_select(rewards):
    """argmax_i r_i (greedy best-of-n)."""
    return jnp.argmax(rewards, axis=-1)


def soft_bon_weights(rewards, beta: float):
    return jax.nn.softmax(beta * rewards.astype(jnp.float32), axis=-1)
