"""Theorems 1 & 2: bounds, divergences and their Monte-Carlo estimators.

Everything the paper states quantitatively, as code:
  * Theorem 1 n-bound and the KL bound it inverts (Appendix A.1),
  * Theorem 2 golden-reward gap bound (Appendix A.2),
  * S-BoN KL bound, eq. (2) (Verdun et al., 2025),
  * chi^2 Monte-Carlo estimator used for Table 4 (Appendix C.5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Exact divergences for categorical distributions (toy environment)
# ---------------------------------------------------------------------------

def kl_divergence(p, q, eps: float = 1e-12):
    p = jnp.clip(p, 0.0)
    ratio = jnp.log(jnp.clip(p, eps)) - jnp.log(jnp.clip(q, eps))
    return jnp.sum(jnp.where(p > 0, p * ratio, 0.0), axis=-1)


def chi2_divergence(p, q, eps: float = 1e-12):
    """chi^2(P || Q) = sum_y P(y)^2 / Q(y) - 1."""
    return jnp.sum(jnp.where(p > 0, p * p / jnp.clip(q, eps), 0.0),
                   axis=-1) - 1.0


# ---------------------------------------------------------------------------
# Theorem 1
# ---------------------------------------------------------------------------

def theorem1_n_bound(chi2, beta: float, r_max: float, eps: float):
    """Smallest n guaranteeing KL(pi_{beta,B} || pi~_GSI) <= eps."""
    num = (chi2 + 1.0) * jnp.exp(2.0 * beta * r_max) - 1.0
    return num / (jnp.exp(eps) - 1.0)


def theorem1_kl_bound(n, chi2, beta: float, r_max: float):
    """KL bound as a function of n (the last display of the A.1 proof)."""
    n = jnp.asarray(n, jnp.float32)
    return jnp.log((chi2 + 1.0) * jnp.exp(2.0 * beta * r_max) / n
                   + (n - 1.0) / n)


def sbon_kl_bound(n, pi_B, r, beta: float):
    """Eq. (2): KL(pi_{beta,B} || pi^n_{beta,B}) <= log(1 + Var/(n E^2))."""
    w = jnp.exp(beta * r)
    e = jnp.sum(pi_B * w, axis=-1)
    var = jnp.sum(pi_B * (w - e[..., None]) ** 2, axis=-1)
    return jnp.log1p(var / (n * e ** 2))


# ---------------------------------------------------------------------------
# Theorem 2
# ---------------------------------------------------------------------------

def coefficient_of_variation(pi_B, r, beta: float):
    """CV(e^{beta r}) under pi_B."""
    w = jnp.exp(beta * r)
    e = jnp.sum(pi_B * w, axis=-1)
    var = jnp.sum(pi_B * (w - e[..., None]) ** 2, axis=-1)
    return jnp.sqrt(var) / e


def theorem2_gap_bound(n, p_accept, chi2, cv, beta: float, r_max: float,
                       r_star_max: float):
    """E_{pi_{beta,B}}[r*] - E_{pi_GSI}[r*] <= this (Theorem 2, formal)."""
    n = jnp.asarray(n, jnp.float32)
    term_a = jnp.sqrt(p_accept) * jnp.exp(beta * r_max) * jnp.sqrt(chi2 + 1.0)
    term_b = (1.0 - p_accept) ** 0.25 * jnp.sqrt(cv ** 2 + 1.0)
    return r_star_max / jnp.sqrt(n) * (term_a + term_b)


# ---------------------------------------------------------------------------
# Monte-Carlo estimators (Appendix C.5, Table 4)
# ---------------------------------------------------------------------------

def chi2_mc_estimate(logp_B, logp_S):
    """(1/N) sum_i (exp(logp_B_i - logp_S_i) - 1)^2 with y_i ~ pi_S.

    The paper's per-step estimator: logp arrays of shape (N,).
    """
    ratio = jnp.exp(jnp.clip(logp_B - logp_S, -30.0, 30.0))
    return jnp.mean((ratio - 1.0) ** 2)


def kl_mc_estimate(p_exact, empirical_counts, eps: float = 1e-9):
    """KL(P || Q_hat) with Q_hat from MC counts (add-eps smoothing)."""
    q = (empirical_counts + eps)
    q = q / jnp.sum(q, axis=-1, keepdims=True)
    return kl_divergence(p_exact, q)
