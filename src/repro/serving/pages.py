"""Host-side page allocator for the paged KV-cache (generalizes SlotPool).

The paged serving state replaces the per-slot dense ``(B, max_seq, KV, hd)``
caches with fixed page pools — per model and per attention layer a
``(num_pages + scratch + 1, page_size, KV, hd)`` K/V array — plus ONE
per-slot block table ``pt: (B, nblk + 1) int32`` shared by all three models
(draft / target / PRM advance ``pos`` in lockstep, so page ``p`` is row ``p``
of every attention-layer pool simultaneously).  :class:`PagePool` is the
host-side ledger over the ``num_pages`` allocatable ids:

  * **reservation** — admission control *claims* a request's worst-case page
    count up front (``claim``), so a mid-flight request can never hit an
    out-of-pages condition; the scheduler defers queued requests while
    ``can_claim`` is False (backpressure, never drops).
  * **lazy assignment** — pages are only *assigned* to table blocks as
    ``pos`` actually approaches them (``ensure``), so a request that
    finishes early never touches most of its claim.
  * **reclamation** — ``release`` returns both assigned pages and the
    unused remainder of the claim to the free list; no zeroing is needed
    (the decode mask hides every position beyond a slot's ``pos``, and a
    page is always written before the mask can expose it).

Beyond the allocatable ids the device pools carry two static regions the
allocator never touches: ``batch * n * span`` *scratch* pages used by the
jitted draft/target phases for copy-on-write candidate branching, and one
*trash* page (the last row) that absorbs the engine's benign
garbage-at-``pos`` writes for rows that are done or never admitted.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Tuple


def pages_for(positions: int, page_size: int) -> int:
    """Pages needed to hold ``positions`` cache positions (ceil)."""
    return -(-positions // page_size)


@dataclass
class PagePool:
    """Ledger over ``num_pages`` allocatable page ids (0..num_pages-1)."""
    num_pages: int
    page_size: int
    free: List[int] = field(default=None)
    claimed: Dict[int, int] = field(default_factory=dict)   # slot -> unassigned claim
    assigned: Dict[int, List[int]] = field(default_factory=dict)  # slot -> pages by block
    peak_assigned: int = 0
    peak_in_use: int = 0          # assigned + outstanding claims

    def __post_init__(self):
        if self.free is None:
            # pop() takes from the end: keep ids ascending for readability
            self.free = list(range(self.num_pages - 1, -1, -1))

    # -- queries -------------------------------------------------------
    @property
    def num_free(self) -> int:
        return len(self.free)

    @property
    def num_assigned(self) -> int:
        return sum(len(v) for v in self.assigned.values())

    @property
    def num_claimed(self) -> int:
        """Pages reserved by admission control but not yet assigned."""
        return sum(self.claimed.values())

    @property
    def num_in_use(self) -> int:
        return self.num_assigned + self.num_claimed

    def can_claim(self, pages: int) -> bool:
        return self.num_free - self.num_claimed >= pages

    def blocks_assigned(self, slot: int) -> int:
        return len(self.assigned.get(slot, ()))

    # -- transitions ---------------------------------------------------
    def claim(self, slot: int, pages: int) -> None:
        """Reserve ``pages`` for ``slot`` (admission control)."""
        if slot in self.claimed or slot in self.assigned:
            raise ValueError(f"slot {slot} already holds a claim")
        if not self.can_claim(pages):
            raise ValueError(
                f"cannot claim {pages} pages: {self.num_free} free, "
                f"{self.num_claimed} already claimed")
        self.claimed[slot] = pages
        self.assigned[slot] = []
        self.peak_in_use = max(self.peak_in_use, self.num_in_use)

    def ensure(self, slot: int, nblocks: int) -> List[Tuple[int, int]]:
        """Assign pages so ``slot`` covers table blocks [0, nblocks).

        Draws from the slot's claim; returns the new (block, page) pairs
        (empty if already covered).  Called by the engine host loop before
        every jitted phase that may write new blocks.
        """
        if slot not in self.assigned:
            raise ValueError(f"slot {slot} has no claim")
        pages = self.assigned[slot]
        new = []
        while len(pages) < nblocks:
            if self.claimed[slot] <= 0:
                raise ValueError(
                    f"slot {slot} exceeded its page claim (needs block "
                    f"{len(pages)}; admission control under-reserved)")
            page = self.free.pop()
            self.claimed[slot] -= 1
            new.append((len(pages), page))
            pages.append(page)
        if new:
            self.peak_assigned = max(self.peak_assigned, self.num_assigned)
        return new

    def release(self, slot: int) -> int:
        """Free the slot's assigned pages and drop its remaining claim."""
        if slot not in self.assigned:
            raise ValueError(f"slot {slot} has no claim")
        pages = self.assigned.pop(slot)
        self.free.extend(reversed(pages))
        self.claimed.pop(slot, None)
        return len(pages)
