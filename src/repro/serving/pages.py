"""Host-side page allocator for the paged KV-cache (generalizes SlotPool).

The paged serving state replaces the per-slot dense ``(B, max_seq, KV, hd)``
caches with fixed page pools — per model and per attention layer a
``(num_pages + scratch + 1, page_size, KV, hd)`` K/V array — plus ONE
per-slot block table ``pt: (B, nblk + 1) int32`` shared by all three models
(draft / target / PRM advance ``pos`` in lockstep, so page ``p`` is row ``p``
of every attention-layer pool simultaneously).  :class:`PagePool` is the
host-side ledger over the ``num_pages`` allocatable ids:

  * **reservation** — admission control *claims* a request's worst-case page
    count up front (``claim``), so a mid-flight request can never hit an
    out-of-pages condition; the scheduler defers queued requests while
    ``can_claim`` is False (backpressure, never drops).
  * **lazy assignment** — pages are only *assigned* to table blocks as
    ``pos`` actually approaches them (``ensure``), so a request that
    finishes early never touches most of its claim.
  * **refcounted sharing** — a page may back the same block of several
    slots at once (cross-request prefix sharing): each slot referencing a
    page holds one refcount, ``release`` decrements instead of freeing, and
    a page only leaves circulation when its last reader drops it.
  * **content-addressed reuse** — an attached :class:`RadixIndex` keys
    *full, committed* pages by their page-size token chunk.  ``match``
    finds the longest cached page-aligned prefix of a new prompt;
    ``publish`` registers a prompt's full pages after their prefill commit.
    Pages retained by the index survive their last reader (they park in a
    ``cached`` LRU set) and are resurrected by later matches.
  * **eviction over deferral** — when a claim would not fit, ``claim``
    evicts least-recently-used *unreferenced* cached pages (whole radix
    subtrees, so the trie never holds unreachable pages) before giving up;
    admission only defers once free + evictable pages are truly exhausted.

Every allocatable page is in exactly one of three states — on the ``free``
list, *referenced* (refcount > 0; assigned to at least one slot), or
*cached* (refcount == 0 but retained by the radix index) — and
``free + referenced + cached == num_pages`` always holds (the property
tests drive random interleavings against exactly this invariant).

Beyond the allocatable ids the device pools carry two static regions the
allocator never touches: ``batch * n * span`` *scratch* pages used by the
jitted draft/target phases for copy-on-write candidate branching, and one
*trash* page (the last row) that absorbs the engine's benign
garbage-at-``pos`` writes for rows that are done or never admitted.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.kernels import quant
from repro.serving.radix import RadixIndex, RadixNode  # noqa: F401 (re-export)


def pages_for(positions: int, page_size: int) -> int:
    """Pages needed to hold ``positions`` cache positions (ceil)."""
    return -(-positions // page_size)


@dataclass
class PagePool:
    """Ledger over ``num_pages`` allocatable page ids (0..num_pages-1)."""
    num_pages: int
    page_size: int
    index: Optional[RadixIndex] = None    # attached = prefix caching on
    kv_dtype: Optional[str] = None        # page storage format (see quant)
    page_bytes: int = 0                   # bytes per page (0 = uniform LRU)
    page_cost_override: Dict[int, int] = field(default_factory=dict)
    free: List[int] = field(default=None)
    claimed: Dict[int, int] = field(default_factory=dict)   # slot -> unassigned claim
    assigned: Dict[int, List[int]] = field(default_factory=dict)  # slot -> pages by block
    refcount: Dict[int, int] = field(default_factory=dict)  # page -> live slot refs (>0)
    retained: Set[int] = field(default_factory=set)         # pages held by the index
    cached: Set[int] = field(default_factory=set)           # retained, refcount == 0
    scale_slots: Set[int] = field(default_factory=set)      # pages w/ live scales
    evicted: int = 0              # lifetime cached pages evicted (stats)
    peak_assigned: int = 0        # peak *distinct* referenced pages (HBM)
    peak_in_use: int = 0          # referenced + outstanding claims

    def __post_init__(self):
        """Seed the free list with every allocatable page id."""
        quant.validate_kv_dtype(self.kv_dtype)
        if self.free is None:
            # pop() takes from the end: keep ids ascending for readability
            self.free = list(range(self.num_pages - 1, -1, -1))

    @property
    def quantized(self) -> bool:
        """True when pages carry per-page scale tensors (int8 / fp8).

        A quantized pool tracks ``scale_slots``: the set of pages whose
        scale entry is live on device.  A page's scale slot is claimed
        the moment the page leaves circulation's free pool (first
        reference) and released only when the page itself returns to the
        free list — so scales are claimed / released / evicted in
        lockstep with their page, and
        ``scale_slots == referenced | cached`` always holds.
        """
        return quant.is_quantized(self.kv_dtype)

    # -- queries -------------------------------------------------------
    @property
    def num_free(self) -> int:
        """Pages on the free list (unclaimed, unreferenced, unretained)."""
        return len(self.free)

    @property
    def num_assigned(self) -> int:
        """Slot-side view: sum of per-slot block counts (a shared page is
        counted once per slot referencing it)."""
        return sum(len(v) for v in self.assigned.values())

    @property
    def num_referenced(self) -> int:
        """Distinct pages with at least one live slot reference."""
        return len(self.refcount)

    @property
    def num_cached(self) -> int:
        """Unreferenced pages retained by the radix index (evictable)."""
        return len(self.cached)

    @property
    def num_claimed(self) -> int:
        """Pages reserved by admission control but not yet assigned."""
        return sum(self.claimed.values())

    @property
    def num_in_use(self) -> int:
        """Referenced pages plus outstanding (unassigned) reservations."""
        return self.num_referenced + self.num_claimed

    def can_claim(self, pages: int, shared: Sequence[int] = ()) -> bool:
        """Would a ``pages``-page claim (on top of ``shared`` matched pages
        about to be pinned) fit, counting LRU-evictable cached pages?"""
        evictable = self.num_cached - sum(1 for p in shared
                                          if p in self.cached)
        return self.num_free + evictable - self.num_claimed >= pages

    def blocks_assigned(self, slot: int) -> int:
        """Table blocks the slot's claim has materialized so far."""
        return len(self.assigned.get(slot, ()))

    def max_blocks(self, slot: int) -> int:
        """Ceiling on the slot's table blocks: assigned + remaining claim.

        Pipelined page assignment looks ahead one decode step per
        in-flight ticket; clamping the look-ahead here keeps a
        conservative estimate from ever out-running the admission
        reservation (the slot is force-done before it could write there).
        """
        return len(self.assigned.get(slot, ())) + self.claimed.get(slot, 0)

    # -- refcount plumbing ---------------------------------------------
    def _ref(self, page: int) -> None:
        rc = self.refcount.get(page, 0)
        if rc == 0:
            self.cached.discard(page)     # referenced pages leave the LRU
            if self.quantized:
                self.scale_slots.add(page)    # claimed with the page
        self.refcount[page] = rc + 1

    def _unref(self, page: int) -> None:
        rc = self.refcount[page] - 1
        if rc > 0:
            self.refcount[page] = rc
            return
        del self.refcount[page]
        if page in self.retained:
            self.cached.add(page)         # survives: radix cache entry
        else:
            self.free.append(page)
            self.scale_slots.discard(page)    # released with the page

    # -- prefix cache --------------------------------------------------
    def match(self, tokens) -> Tuple[List[int], int]:
        """Radix lookup: (shareable pages, matched token count)."""
        if self.index is None:
            return [], 0
        return self.index.match(tokens)

    def publish(self, tokens, pages: Sequence[int]) -> int:
        """Register a prompt's full committed pages in the radix index
        (called after their prefill commit is ordered on the device
        stream).  Duplicate chunks keep the first writer's page.  Returns
        the number of pages newly retained.

        The caller must hold a reference to every page it publishes —
        retaining a free page would let the trie serve it while ``ensure``
        hands it to a new writer, so that misuse raises instead.
        """
        if self.index is None or not pages:
            return 0
        if any(p not in self.refcount for p in pages):
            raise ValueError(
                "publish requires the caller to hold a reference to "
                "every published page")
        new = self.index.insert(tokens, pages)
        self.retained.update(new)
        return len(new)

    def page_cost(self, page: int) -> int:
        """Eviction cost of a cached page, in bytes.

        Defaults to the pool-wide ``page_bytes`` (what the engine wires
        in from its memory report — a cached int8 page costs half a bf16
        one, so it survives proportionally longer under the
        bytes-weighted LRU).  ``page_cost_override`` supplies per-page
        costs for heterogeneous pools and tests; ``0``/unset everywhere
        degenerates to uniform cost, i.e. plain LRU.
        """
        return self.page_cost_override.get(page, self.page_bytes) or 1

    def evict(self, need: int) -> int:
        """Evict cached pages until ``need`` are freed, cheapest-score
        first.

        The victim order is the bytes-weighted LRU of
        :meth:`RadixIndex.lru_page`: among unreferenced cached pages the
        one minimizing ``clock / page_cost`` goes first — old *and*
        expensive pages are reclaimed before young or cheap (quantized)
        ones, and uniform costs reduce to plain LRU.  Whole radix
        subtrees are dropped at once so no page is left
        retained-but-unreachable: refcount-0 pages of the subtree go back
        to the free list now, still-referenced ones merely lose their cache
        retention and will be freed by their last ``release``.
        """
        freed = 0
        while freed < need and self.cached:
            page = self.index.lru_page(self.cached, cost=self.page_cost)
            if page is None:              # cached page vanished from trie
                stray = self.cached.pop()
                self.retained.discard(stray)
                self.free.append(stray)
                self.scale_slots.discard(stray)   # evicted with the page
                freed += 1
                self.evicted += 1
                continue
            for p in self.index.drop_subtree(page):
                self.retained.discard(p)
                if p in self.cached:
                    self.cached.remove(p)
                    self.free.append(p)
                    self.scale_slots.discard(p)   # evicted with the page
                    freed += 1
                    self.evicted += 1
        return freed

    def forget(self, page: int) -> int:
        """Drop the radix subtree rooted at ``page``'s node without the
        LRU victim selection of :meth:`evict` — the cache-migration
        primitive (the source replica forgets a preamble group after
        its pages were pushed to the destination, so tier-1 affinity
        stops matching it here).

        Refcount-0 pages of the subtree return to the free list (scale
        slots released in lockstep); still-referenced pages merely lose
        their retention and will be freed by their last ``release``.
        Returns the number of pages actually freed.  Not counted as an
        eviction (``evicted`` tracks pressure evictions only).
        """
        if self.index is None:
            return 0
        freed = 0
        for p in self.index.drop_subtree(page):
            self.retained.discard(p)
            if p in self.cached:
                self.cached.remove(p)
                self.free.append(p)
                self.scale_slots.discard(p)
                freed += 1
        return freed

    # -- transitions ---------------------------------------------------
    def claim(self, slot: int, pages: int,
              shared: Sequence[int] = ()) -> None:
        """Reserve ``pages`` *tail* pages for ``slot`` (admission control),
        seeding its block table with the matched ``shared`` pages.

        Pins ``shared`` first (so eviction can never free the very pages
        being spliced), then evicts cached pages as needed to fit the tail
        reservation; raises only if free + evictable is still short.
        """
        if slot in self.claimed or slot in self.assigned:
            raise ValueError(f"slot {slot} already holds a claim")
        for p in shared:
            self._ref(p)
        deficit = pages - (self.num_free - self.num_claimed)
        if deficit > 0:
            self.evict(deficit)
        if self.num_free - self.num_claimed < pages:
            for p in shared:              # unwind the pins
                self._unref(p)
            raise ValueError(
                f"cannot claim {pages} pages: {self.num_free} free, "
                f"{self.num_cached} cached, "
                f"{self.num_claimed} already claimed")
        self.claimed[slot] = pages
        self.assigned[slot] = list(shared)
        self.peak_assigned = max(self.peak_assigned, self.num_referenced)
        self.peak_in_use = max(self.peak_in_use, self.num_in_use)

    def ensure(self, slot: int, nblocks: int) -> List[Tuple[int, int]]:
        """Assign pages so ``slot`` covers table blocks [0, nblocks).

        Draws from the slot's claim; returns the new (block, page) pairs
        (empty if already covered).  Called by the engine host loop before
        every jitted phase that may write new blocks.
        """
        if slot not in self.assigned:
            raise ValueError(f"slot {slot} has no claim")
        pages = self.assigned[slot]
        new = []
        while len(pages) < nblocks:
            if self.claimed[slot] <= 0:
                raise ValueError(
                    f"slot {slot} exceeded its page claim (needs block "
                    f"{len(pages)}; admission control under-reserved)")
            page = self.free.pop()
            self.claimed[slot] -= 1
            self._ref(page)
            new.append((len(pages), page))
            pages.append(page)
        if new:
            self.peak_assigned = max(self.peak_assigned,
                                     self.num_referenced)
        return new

    def release(self, slot: int) -> int:
        """Drop the slot's references and its remaining claim.

        Shared pages with other live readers survive untouched; pages
        retained by the radix index park in the cached LRU set; everything
        else returns to the free list.  No zeroing is needed (the decode
        mask hides every position beyond a slot's ``pos``, and a page is
        always written before the mask can expose it).
        """
        if slot not in self.assigned:
            raise ValueError(f"slot {slot} has no claim")
        pages = self.assigned.pop(slot)
        for page in reversed(pages):
            self._unref(page)
        self.claimed.pop(slot, None)
        return len(pages)
