"""Analytic latency model (roofline-based) for the paper's Table 1 / Fig. 4.

This container is CPU-only, so end-to-end seconds are reconstructed from the
same three-term roofline used in EXPERIMENTS §Roofline: per phase,
time = max(compute, memory) with

  decode   (per token)  — memory-bound: bytes = params + KV-cache read
  scoring  (per step)   — one parallel forward: compute-bound at n*L tokens
  PRM      (per step)   — ditto
  prefill  (per sample) — one parallel forward per model over the prompt
                          *tail* only: the radix prefix cache splices the
                          matched pages, so prefill compute is discounted
                          by the measured prefix hit length.

fed with acceptance rates, step lengths and prefix hit lengths *measured*
from the engine.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class Hardware:
    """Peak accelerator numbers the roofline maxes against."""

    name: str
    flops: float          # peak bf16 FLOP/s per chip
    hbm_bw: float         # bytes/s per chip
    chips: int = 1


HW_V5E = Hardware("tpu-v5e", 197e12, 819e9)


@dataclass
class ModelCost:
    """Per-model roofline inputs (active params, KV bytes per token)."""

    params: int           # active params per token
    kv_bytes_per_tok: int

    def decode_time(self, hw: Hardware, ctx_len: int, batch: int) -> float:
        """One decode step for `batch` rows (memory-bound path)."""
        weight_bytes = 2 * self.params  # bf16
        cache_bytes = batch * self.kv_bytes_per_tok * ctx_len
        mem = (weight_bytes + cache_bytes) / (hw.hbm_bw * hw.chips)
        comp = batch * 2 * self.params / (hw.flops * hw.chips)
        return max(mem, comp)

    def forward_time(self, hw: Hardware, tokens: int) -> float:
        """Parallel scoring/prefill over `tokens` tokens (compute path)."""
        comp = tokens * 2 * self.params / (hw.flops * hw.chips)
        mem = 2 * self.params / (hw.hbm_bw * hw.chips)
        return max(mem, comp)


class LatencyModel:
    """Roofline latency model over a draft/target/PRM triple."""

    def __init__(self, draft: ModelCost, target: ModelCost, prm: ModelCost,
                 hw: Hardware = HW_V5E):
        """Bind the three model costs to one hardware description."""
        self.draft, self.target, self.prm, self.hw = draft, target, prm, hw

    def step_time(self, *, method: str, n: int, step_len: float,
                  ctx_len: float, accept_rate: float = 1.0) -> float:
        """Seconds per reasoning step for one request (batch of n samples)."""
        hw = self.hw
        draft_gen = step_len * self.draft.decode_time(hw, ctx_len, n)
        target_gen = step_len * self.target.decode_time(hw, ctx_len, n)
        score_b = self.target.forward_time(hw, n * step_len)
        prm_t = self.prm.forward_time(hw, n * step_len)

        if method == "sbon_s":
            return draft_gen + prm_t
        if method == "sbon_b":
            return target_gen + prm_t
        if method == "rsd":
            return draft_gen + prm_t + (1 - accept_rate) * (target_gen + prm_t)
        if method in ("gsi", "gsi_norej"):
            t = draft_gen + prm_t + score_b
            if method == "gsi":
                t += (1 - accept_rate) * (target_gen + prm_t)
            return t
        raise ValueError(method)

    def prefill_time(self, prompt_len: float,
                     prefix_hit_len: float = 0.0) -> float:
        """Seconds to prefill a prompt across the three models, with the
        first ``prefix_hit_len`` tokens served from the radix prefix cache
        (their KV pages are spliced, not recomputed).  All three models
        skip the same span — the unified page-id space keeps draft /
        target / PRM position-aligned, so one match discounts every
        prefill."""
        tail = max(float(prompt_len) - float(prefix_hit_len), 0.0)
        if tail <= 0.0:
            return 0.0
        return sum(m.forward_time(self.hw, tail)
                   for m in (self.draft, self.target, self.prm))

    def sample_time(self, *, method: str, n: int, steps: float,
                    step_len: float, accept_rate: float = 1.0,
                    prompt_len: float = 0.0,
                    prefix_hit_len: float = 0.0) -> float:
        """End-to-end seconds per sample (prefill, then ctx grows step by
        step).  ``prompt_len``/``prefix_hit_len`` add the prefill term and
        its prefix-cache discount; the default 0 keeps the historical
        decode-only accounting."""
        total = self.prefill_time(prompt_len, prefix_hit_len)
        for s in range(int(round(steps))):
            ctx = prompt_len + (s + 0.5) * step_len
            total += self.step_time(method=method, n=n, step_len=step_len,
                                    ctx_len=ctx, accept_rate=accept_rate)
        return total
