"""Radix (token-trie) index over full, committed KV-cache pages.

One node is one *full* page: a ``page_size`` chunk of some prompt's token
prefix, so the path from the root spells the token prefix and the pages
along it are exactly the KV pages a new request with that prefix can splice
into its block table.  Token chunks are compared exactly (they are dict
keys), so a "hash hit" can never alias two different prefixes.

``clock`` is a logical LRU timestamp: every match and insert touches the
whole path it walks, so a parent is always at least as recent as its
children and the LRU minimum sits leaf-ward — eviction (PagePool.evict)
drops whole subtrees, which keeps the trie free of unreachable pages.

This module is deliberately dependency-free host-side bookkeeping; the
refcounted page ledger that owns it lives in ``repro.serving.pages``.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Set, Tuple


class RadixNode:
    """One full committed page: a page-size chunk of the token prefix."""

    __slots__ = ("key", "page", "parent", "children", "clock")

    def __init__(self, key, page, parent, clock):
        """Node for token chunk ``key`` holding page id ``page``."""
        self.key = key
        self.page = page
        self.parent = parent
        self.children: Dict[Tuple[int, ...], RadixNode] = {}
        self.clock = clock


class RadixIndex:
    """Token-trie over full committed pages (one node == one page)."""

    def __init__(self, page_size: int):
        """Empty trie over ``page_size``-token chunks."""
        self.page_size = page_size
        self.root = RadixNode(None, None, None, 0)
        self.nodes: Dict[int, RadixNode] = {}
        self.clock = 0

    def _tick(self) -> int:
        self.clock += 1
        return self.clock

    def __len__(self) -> int:
        """Number of pages (== nodes) the trie currently retains."""
        return len(self.nodes)

    def _chunks(self, tokens):
        ps = self.page_size
        for j in range(len(tokens) // ps):
            lo = j * ps
            hi = lo + ps
            yield tuple(int(t) for t in tokens[lo:hi])

    def match(self, tokens) -> Tuple[List[int], int]:
        """Longest cached page-aligned prefix of ``tokens``.

        Returns ``(pages, matched_tokens)`` with ``matched_tokens`` equal to
        ``len(pages) * page_size``; touches the matched path (LRU).
        """
        node = self.root
        pages: List[int] = []
        t = self._tick()
        for key in self._chunks(tokens):
            child = node.children.get(key)
            if child is None:
                break
            child.clock = t
            node = child
            pages.append(node.page)
        return pages, len(pages) * self.page_size

    def insert(self, tokens, pages: Sequence[int]) -> List[int]:
        """Register ``pages`` (one per full page-size chunk of ``tokens``).

        Walks/extends the trie; chunks already present keep their existing
        page (the caller's duplicate page stays plain slot-owned and is
        freed on release).  Returns the page ids newly retained here.
        """
        node = self.root
        new: List[int] = []
        t = self._tick()
        for key, page in zip(self._chunks(tokens), pages):
            child = node.children.get(key)
            if child is None:
                child = RadixNode(key, int(page), node, t)
                node.children[key] = child
                self.nodes[int(page)] = child
                new.append(int(page))
            child.clock = t
            node = child
        return new

    def lru_page(self, among: Set[int], cost=None) -> Optional[int]:
        """The page in ``among`` whose node is least recently used.

        ``cost`` (page -> positive int, typically the page's bytes)
        weights the eviction priority: the victim minimizes
        ``clock / cost``, so between equally-stale pages the *expensive*
        one goes first, and a cheap page (a cached int8 page costs half
        a bf16 one) must be proportionally staler to be chosen over a
        costly newer one.  The comparison is exact integer
        cross-multiplication — no float ties — and a uniform cost
        reduces it to plain LRU, clock alone.

        Deterministic tie-break: the lowest page id wins at equal
        scores (``sorted`` iteration + strict ``<``).
        """
        best = None
        best_clock = None
        best_cost = 1
        for page in sorted(among):
            node = self.nodes.get(page)
            if node is None:
                continue
            c = 1 if cost is None else max(1, int(cost(page)))
            # node.clock / c < best_clock / best_cost, exactly
            if best_clock is None \
                    or node.clock * best_cost < best_clock * c:
                best = page
                best_clock = node.clock
                best_cost = c
        return best

    def groups(self) -> List[Tuple[int, ...]]:
        """First-chunk keys of the root's children (preamble groups).

        Each key names one independently evictable/migratable subtree:
        the router's hash tiers place requests by exactly this chunk,
        so it is the unit rendezvous cache migration moves and the
        ``roots`` filter of ``serving.snapshot`` selects by.  Sorted
        for deterministic iteration.
        """
        return sorted(self.root.children)

    def drop_subtree(self, page: int) -> List[int]:
        """Detach the node owning ``page`` plus its whole subtree.

        Returns every page id the subtree retained (subtree root first).
        """
        node = self.nodes.get(page)
        if node is None:
            return []
        del node.parent.children[node.key]
        dropped: List[int] = []
        stack = [node]
        while stack:
            n = stack.pop()
            dropped.append(n.page)
            self.nodes.pop(n.page, None)
            stack.extend(n.children.values())
        return dropped
