"""Hot-cache snapshot/restore codec for the radix prefix cache.

A warm radix cache is the difference between a restarted (or newly
added) replica serving its first requests from spliced KV pages and a
cold-cache prefill storm.  This module serializes the *evictable* part
of a paged engine's prefix cache — the refcount-free ``cached`` pages,
their token chunk keys and LRU clocks, and (for quantized pools) their
per-page scale rows — and restores it into another live state without
ever disturbing pages the allocator has handed out.

Two layers:

* **Record layer** (:func:`index_records` / :func:`restore_records`) —
  pure host bookkeeping over a :class:`~repro.serving.pages.PagePool`
  and its :class:`~repro.serving.radix.RadixIndex`.  A record is one
  trie node: ``(chunk, clock, page, parent)`` with ``parent`` an index
  into the record list (-1 = child of the root).  Restoration *remaps*
  page ids through the destination pool's free list: every restored
  node gets a freshly popped free page, so a snapshot can never
  resurrect a page id that is currently referenced by a live slot.
  Hottest-first admission (descending clock, parents before children)
  keeps the most recently used subtrees when the destination has fewer
  free pages than the snapshot has records.
* **Payload layer** (:func:`snapshot_state` / :func:`restore_state`) —
  gathers the recorded pages' rows out of every paged cache pool leaf
  (``kp``/``vp`` payloads and ``ks``/``vs`` quantized scale rows, in
  one batched ``device_get``) and scatters them back at the remapped
  page ids.  Codes and scales round-trip byte-identically; the page
  conservation ledger (``free + referenced + cached == num_pages``,
  ``scale_slots == referenced | cached``) holds after every restore.

:func:`save_snapshot` / :func:`load_snapshot` put a snapshot on disk as
a single ``.npz`` (used by ``launch/serve.py --cache-dir`` warm
restarts); :meth:`GSIServingEngine.save_cache` / ``load_cache`` are the
engine-level entry points, and :meth:`ReplicaRouter.add_replica` drives
the same codec for rendezvous cache migration.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.engine import _is_paged, _is_stacked
from repro.serving.pages import PagePool
from repro.serving.radix import RadixNode

# one record = one trie node: (chunk, clock, page, parent record index)
Record = Tuple[Tuple[int, ...], int, int, int]


def _path_str(path) -> str:
    """Stable string key for a cache-pytree path (dict keys and list
    indices joined with '.'), used to name payload leaves."""
    parts = []
    for p in path:
        k = getattr(p, "key", None)
        if k is None:
            k = getattr(p, "idx", None)
        parts.append(str(k))
    return ".".join(parts)


def index_records(pool: PagePool,
                  roots: Optional[Sequence[Sequence[int]]] = None
                  ) -> List[Record]:
    """Extract the snapshot records of ``pool``'s radix index.

    Walks the trie preorder (parents always precede their children in
    the returned list) and keeps only the *cached closure*: descent
    stops at the first page that is not in ``pool.cached`` — pages with
    live readers stay with their slots, and a subtree hanging under a
    referenced page is unreachable for restore anyway (its path would
    be broken).  ``roots`` optionally restricts the walk to the given
    first-chunk (preamble-group) keys — the unit the router migrates.
    """
    index = pool.index
    if index is None:
        return []
    want = None if roots is None else \
        {tuple(int(t) for t in r) for r in roots}
    out: List[Record] = []
    stack: List[Tuple[RadixNode, int]] = []
    for key in sorted(index.root.children, reverse=True):
        if want is not None and key not in want:
            continue
        stack.append((index.root.children[key], -1))
    while stack:
        node, parent = stack.pop()
        if node.page not in pool.cached:
            continue                      # referenced: stays with its slot
        rec_idx = len(out)
        out.append((node.key, int(node.clock), int(node.page), parent))
        for key in sorted(node.children, reverse=True):
            stack.append((node.children[key], rec_idx))
    return out


def restore_records(pool: PagePool,
                    records: Sequence[Record]) -> Dict[int, int]:
    """Rebuild snapshot records inside ``pool``'s radix index.

    Returns ``{old_page: new_page}`` for every node actually created —
    the pages whose payload the caller must copy.  Three guarantees:

    * **free-list remap** — new nodes draw their page ids exclusively
      from ``pool.free``; referenced (live) pages are never touched, so
      restoring into a busy engine cannot corrupt in-flight requests.
    * **hottest-first** — records are admitted in descending snapshot
      clock (parents first at equal clocks, which the parent >= child
      clock invariant makes a topological order), so when free pages
      run out the coldest subtrees are the ones dropped.
    * **dedupe** — a chunk already present at its path keeps the
      existing node and page (no allocation, no payload copy); the
      snapshot's children attach underneath it.

    Restored clocks are rebased past the destination's current clock
    (preserving the snapshot's relative LRU order), and ancestors are
    bumped so a parent is never staler than a restored child.
    """
    index = pool.index
    if index is None or not records:
        return {}
    order = sorted(range(len(records)),
                   key=lambda i: (-records[i][1], i))
    min_clock = min(r[1] for r in records)
    base = index.clock + 1
    node_of: Dict[int, RadixNode] = {}
    remap: Dict[int, int] = {}
    max_clock = index.clock
    for i in order:
        key, clock, old_page, parent = records[i]
        if parent == -1:
            pnode = index.root
        else:
            pnode = node_of.get(parent)
            if pnode is None:             # parent dropped: branch is dead
                continue
        new_clock = base + (clock - min_clock)
        existing = pnode.children.get(key)
        if existing is not None:
            existing.clock = max(existing.clock, new_clock)
            node_of[i] = existing
            max_clock = max(max_clock, existing.clock)
            continue
        if len(pool.free) <= pool.num_claimed:
            # free pages backing outstanding admission reservations are
            # spoken for — taking one would let a live slot's ensure()
            # pop an empty free list.  Keep the hottest, drop the rest.
            continue
        page = pool.free.pop()
        node = RadixNode(key, page, pnode, new_clock)
        pnode.children[key] = node
        index.nodes[page] = node
        pool.retained.add(page)
        pool.cached.add(page)
        if pool.quantized:
            pool.scale_slots.add(page)    # restored with the page
        node_of[i] = node
        remap[old_page] = page
        max_clock = max(max_clock, new_clock)
        anc = pnode                       # parent at least as recent
        while anc is not index.root and anc.clock < new_clock:
            anc.clock = new_clock
            anc = anc.parent
    index.clock = max(index.clock, max_clock)
    return remap


def snapshot_state(engine, state,
                   roots: Optional[Sequence[Sequence[int]]] = None) -> dict:
    """Snapshot the engine's cached radix subtrees out of ``state``.

    Returns a host-side snapshot dict: the index records as flat arrays
    (``chunks``/``clocks``/``parents``/``pages``) plus one gathered
    payload array per paged cache leaf (``kp``/``vp`` pages and, when
    quantized, ``ks``/``vs`` scale rows), pulled in a single batched
    ``device_get``.  ``roots`` restricts the snapshot to the given
    preamble-group chunks (cache migration); ``None`` takes everything
    cached.  An engine without a live prefix cache yields an empty
    snapshot (restoring it is a no-op).
    """
    snap = {
        "page_size": engine.page_size,
        "kv_dtype": getattr(engine, "kv_dtype", None),
        "chunks": np.zeros((0, engine.page_size), np.int32),
        "clocks": np.zeros((0,), np.int64),
        "parents": np.zeros((0,), np.int32),
        "pages": np.zeros((0,), np.int32),
        "leaves": {},
    }
    if not getattr(engine, "paged", False) or engine.pager is None \
            or not engine.prefix_cache:
        return snap
    engine._check_gen(state)
    records = index_records(engine.pager, roots=roots)
    if not records:
        return snap
    snap["chunks"] = np.asarray([r[0] for r in records], np.int32)
    snap["clocks"] = np.asarray([r[1] for r in records], np.int64)
    snap["pages"] = np.asarray([r[2] for r in records], np.int32)
    snap["parents"] = np.asarray([r[3] for r in records], np.int32)
    ids = jnp.asarray(snap["pages"])
    gathered = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(
            state["caches"])[0]:
        if not _is_paged(path):
            continue
        axis = 1 if _is_stacked(path) else 0
        gathered[_path_str(path)] = jnp.take(leaf, ids, axis=axis)
    snap["leaves"] = jax.device_get(gathered)
    return snap


def restore_state(engine, state, snapshot: dict):
    """Splice a snapshot's cached subtrees into ``state``; returns the
    new state (the input is not mutated).

    Validates the snapshot's ``page_size``/``kv_dtype`` against the
    engine, rebuilds the index records through the pool's free list
    (:func:`restore_records` — live referenced pages are never
    overwritten) and scatters the accepted records' payload rows into
    every paged cache leaf at their *remapped* page ids.
    """
    if not getattr(engine, "paged", False) or engine.pager is None \
            or not engine.prefix_cache:
        return state
    engine._check_gen(state)
    if int(snapshot["page_size"]) != engine.page_size:
        raise ValueError(
            f"snapshot page_size {snapshot['page_size']} != engine "
            f"page_size {engine.page_size}")
    if (snapshot.get("kv_dtype") or None) != (engine.kv_dtype or None):
        raise ValueError(
            f"snapshot kv_dtype {snapshot.get('kv_dtype')!r} != engine "
            f"kv_dtype {engine.kv_dtype!r} (page payloads would not "
            f"round-trip)")
    pages = np.asarray(snapshot["pages"], np.int64)
    records: List[Record] = [
        (tuple(int(t) for t in snapshot["chunks"][i]),
         int(snapshot["clocks"][i]), int(pages[i]),
         int(snapshot["parents"][i]))
        for i in range(pages.size)]
    remap = restore_records(engine.pager, records)
    if not remap:
        return state
    rows = np.asarray([i for i in range(pages.size)
                       if int(pages[i]) in remap])
    new_ids = jnp.asarray([remap[int(pages[i])] for i in rows],
                          jnp.int32)
    leaves = snapshot["leaves"]

    def put(path, leaf):
        key = _path_str(path)
        if key not in leaves:
            return leaf
        arr = np.asarray(leaves[key])
        if _is_stacked(path):
            return leaf.at[:, new_ids].set(
                jnp.asarray(arr[:, rows], leaf.dtype))
        return leaf.at[new_ids].set(jnp.asarray(arr[rows], leaf.dtype))

    new_state = dict(state)
    new_state["caches"] = jax.tree_util.tree_map_with_path(
        put, state["caches"])
    return new_state


def save_snapshot(snapshot: dict, path) -> None:
    """Write a snapshot dict to ``path`` as a single ``.npz`` file."""
    np.savez(
        path,
        __page_size=np.asarray(int(snapshot["page_size"]), np.int64),
        __kv_dtype=np.asarray(snapshot.get("kv_dtype") or ""),
        __chunks=snapshot["chunks"], __clocks=snapshot["clocks"],
        __parents=snapshot["parents"], __pages=snapshot["pages"],
        **{f"leaf.{k}": v for k, v in snapshot["leaves"].items()})


def load_snapshot(path) -> dict:
    """Read a snapshot ``.npz`` written by :func:`save_snapshot`."""
    with np.load(path) as f:
        return {
            "page_size": int(f["__page_size"]),
            "kv_dtype": str(f["__kv_dtype"]) or None,
            "chunks": f["__chunks"], "clocks": f["__clocks"],
            "parents": f["__parents"], "pages": f["__pages"],
            "leaves": {k[len("leaf."):]: f[k] for k in f.files
                       if k.startswith("leaf.")},
        }
