"""Multi-replica request router with preamble-affinity placement.

:class:`ReplicaRouter` fronts N independent engine/scheduler replicas
(:mod:`repro.serving.replica`) and decides, per request, which replica's
queue it joins.  Three policies:

``affinity`` (default)
    Keep requests that share a prompt preamble on the same replica, so
    that replica's radix prefix cache serves the preamble's KV pages to
    all of them.  Placement is two-tier: first the prompt is matched
    against every replica's radix index and the replica with the
    *longest* cached prefix wins (true longest-preamble affinity —
    pages already live there); on a miss everywhere the request falls
    back to a deterministic hash of its first full page-size token chunk
    (the page-aligned preamble — stable across requests that share a
    preamble, whatever their total length), so a burst of same-preamble
    requests submitted before any page is published still lands on one
    replica.  Prompts shorter than one full page (nothing shareable) and
    placements that would push a replica's load more than ``skew``
    requests past the least-loaded replica fall back to least-loaded.

``round_robin``
    Cycle replicas in submission order (the locality-blind baseline the
    benchmark compares affinity against).

``least_loaded``
    Always the replica with the fewest outstanding requests
    (queued + live slots); ties break to the lowest replica index.

The router assembles id-keyed :class:`Response` objects across replicas
(out-of-order completion included) and aggregates ``prefix_stats()`` /
``EngineStats`` over the fleet.  Replicas share nothing, so per-replica
invariants (page conservation, one-live-state) hold independently —
the hypothesis property test drives routed admissions against exactly
that.
"""
from __future__ import annotations

import hashlib
import time
from typing import Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.serving.gsi_engine import EngineStats, merge_engine_stats
from repro.serving.replica import Replica, build_replicas
from repro.serving.scheduler import Response

POLICIES = ("affinity", "round_robin", "least_loaded")


def preamble_hash(tokens, num_replicas: int) -> int:
    """Deterministic replica index for a token chunk.

    Stable across processes (unlike builtin ``hash``, which is salted),
    so affinity placement is reproducible run to run — the property
    tests and the throughput ``--check`` both rely on that.
    """
    data = np.ascontiguousarray(np.asarray(tokens, np.int32)).tobytes()
    digest = hashlib.blake2b(data, digest_size=8).digest()
    return int.from_bytes(digest, "big") % num_replicas


class ReplicaRouter:
    """Route requests across N independent serving replicas.

    Parameters
    ----------
    engines:   one built :class:`GSIServingEngine` per replica (distinct
               objects — a paged engine backs one live state).
    capacity:  scheduler slots *per replica*.
    policy:    ``affinity`` | ``round_robin`` | ``least_loaded``.
    skew:      affinity-only load guard: if the affine replica's load
               exceeds the least-loaded replica's by more than ``skew``
               requests, route least-loaded instead (None disables the
               guard — pure affinity, used by deterministic checks).
    cache_aware: enable cache-aware admission ordering inside each
               replica (queued requests with live radix matches first).
    continuous / prompt_pad_len / collect_stats: forwarded to each
               replica's :class:`GSIScheduler`.
    """

    def __init__(self, engines, *, capacity: int,
                 policy: str = "affinity", skew: Optional[int] = 4,
                 continuous: bool = True, prompt_pad_len: int = 0,
                 collect_stats: bool = False, cache_aware: bool = True):
        """Build one replica (engine + scheduler) per engine given."""
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}; "
                             f"choose from {POLICIES}")
        self.replicas: List[Replica] = build_replicas(
            engines, capacity=capacity, continuous=continuous,
            prompt_pad_len=prompt_pad_len, collect_stats=collect_stats,
            cache_aware=cache_aware)
        self.policy = policy
        self.skew = skew
        self.capacity = capacity
        self.responses: Dict[str, Response] = {}
        self.routing = {"affinity_matched": 0, "affinity_hashed": 0,
                        "fallback_load": 0}
        self._replica_of: Dict[str, int] = {}
        self._rr = 0
        self._seq = 0

    # ------------------------------------------------------------------
    # Placement
    # ------------------------------------------------------------------
    @property
    def num_replicas(self) -> int:
        """Number of replicas in the fleet."""
        return len(self.replicas)

    def loads(self) -> List[int]:
        """Outstanding requests (queued + live) per replica."""
        return [r.load for r in self.replicas]

    def _least_loaded(self, loads: Sequence[int]) -> int:
        return int(np.argmin(loads))          # ties -> lowest index

    def route(self, prompt) -> int:
        """Pick the replica index for ``prompt`` under the policy.

        Pure placement — no queue mutation; ``submit`` calls this and
        then hands the request to the chosen replica.
        """
        if self.policy == "round_robin":
            i = self._rr
            self._rr = (self._rr + 1) % self.num_replicas
            return i
        loads = self.loads()
        if self.policy == "least_loaded":
            return self._least_loaded(loads)
        return self._route_affinity(np.asarray(prompt,
                                               np.int32).reshape(-1),
                                    loads)

    def _route_affinity(self, prompt: np.ndarray,
                        loads: Sequence[int]) -> int:
        """Longest-preamble affinity with hash seeding and a skew guard.

        Tier 1: the replica whose radix index holds the longest cached
        prefix of ``prompt`` (ties break to the less-loaded replica).
        Tier 2 (no replica has a match): hash the first full page-size
        chunk of the prompt.  Tier 3 (prompt too short to ever share a
        page): least-loaded.  Finally the skew guard may override a
        placement that would unbalance the fleet.
        """
        best, best_len = None, 0
        for rep in self.replicas:
            _, matched = rep.engine.match_prefix(prompt)
            if matched > best_len or (
                    matched == best_len and matched > 0
                    and loads[rep.index] < loads[best]):
                best, best_len = rep.index, matched
        if best is not None:
            tier = "affinity_matched"
        else:
            page_size = self.replicas[0].engine.page_size
            if prompt.size - 1 >= page_size:
                best = preamble_hash(prompt[:page_size],
                                     self.num_replicas)
                tier = "affinity_hashed"
            else:
                self.routing["fallback_load"] += 1
                return self._least_loaded(loads)
        if self.skew is not None and \
                loads[best] - min(loads) > self.skew:
            # exactly one counter per request: a skew override is
            # reported as the fallback it actually was, not as affinity
            self.routing["fallback_load"] += 1
            return self._least_loaded(loads)
        self.routing[tier] += 1
        return best

    # ------------------------------------------------------------------
    # Submission / stepping
    # ------------------------------------------------------------------
    def submit(self, prompt, *, request_id: Optional[str] = None,
               max_steps: Optional[int] = None,
               arrival_time: float = 0.0) -> str:
        """Route a prompt to a replica queue; returns the request id.

        Ids are unique fleet-wide (router-assigned ``req-N`` by default;
        caller-provided ids are checked against every replica).
        """
        if request_id is None:
            # skip ids a caller already used explicitly — a collision
            # would silently overwrite the other request's Response
            while f"req-{self._seq}" in self._replica_of:
                self._seq += 1
            request_id = f"req-{self._seq}"
        elif request_id in self._replica_of:
            raise ValueError(f"request id {request_id!r} already routed "
                             f"to replica {self._replica_of[request_id]}")
        self._seq += 1
        idx = self.route(prompt)
        self.replicas[idx].submit(prompt, request_id=request_id,
                                  max_steps=max_steps,
                                  arrival_time=arrival_time)
        self._replica_of[request_id] = idx
        return request_id

    def replica_of(self, request_id: str) -> int:
        """The replica index a submitted request was routed to."""
        return self._replica_of[request_id]

    def step(self, rng) -> List[Response]:
        """Step every replica once; returns the responses finished now.

        Each replica gets an independent key pair split from ``rng``, so
        a replica's rng stream never depends on how many peers it has or
        on what they decode.  Idle replicas skip their engine step.
        """
        keys = jax.random.split(rng, 2 * self.num_replicas)
        finished: List[Response] = []
        for rep in self.replicas:
            k1, k2 = keys[2 * rep.index], keys[2 * rep.index + 1]
            for resp in rep.step(k1, k2):
                self.responses[resp.request_id] = resp
                finished.append(resp)
        return finished

    def run(self, rng) -> Dict[str, Response]:
        """Drain every replica; returns id -> Response across the fleet.

        Mirrors ``GSIScheduler.run``: while any replica holds work, step
        the fleet; when every live slot is drained and the earliest
        queued arrival is still in the future, sleep until it lands.
        """
        while any(rep.has_work for rep in self.replicas):
            if not any(rep.scheduler.pool.num_live
                       for rep in self.replicas):
                waits = [rep.next_arrival() - rep.scheduler._now()
                         for rep in self.replicas
                         if rep.next_arrival() is not None]
                wait = min(waits) if waits else 0.0
                if wait > 0:
                    time.sleep(min(wait, 0.05))
                    continue
            rng, k = jax.random.split(rng)
            self.step(k)
        return dict(self.responses)

    # ------------------------------------------------------------------
    # Fleet-level stats
    # ------------------------------------------------------------------
    @property
    def engine_steps(self) -> int:
        """Total decode steps across the fleet (sum over replicas).

        Replicas step concurrently in a real deployment, so the
        wall-clock proxy is ``max`` — see ``engine_steps_max``.
        """
        return sum(rep.scheduler.engine_steps for rep in self.replicas)

    @property
    def engine_steps_max(self) -> int:
        """Decode steps of the busiest replica (parallel-time proxy)."""
        return max(rep.scheduler.engine_steps for rep in self.replicas)

    @property
    def stats(self) -> EngineStats:
        """Aggregate EngineStats over the fleet (counters summed,
        trace moments merged exactly, bounded trace lists concatenated).
        """
        return merge_engine_stats([rep.scheduler.stats
                                   for rep in self.replicas])

    def prefix_stats(self) -> Dict[str, object]:
        """Fleet-aggregate prefix-cache counters.

        Same scalar keys as ``GSIScheduler.prefix_stats()`` (counters
        summed, ``hit_rate`` recomputed from the sums) plus
        ``per_replica`` with each replica's own counters — per-replica
        hit-rates are how affinity quality is read.
        """
        per = [rep.scheduler.prefix_stats() for rep in self.replicas]
        agg: Dict[str, object] = {
            k: sum(p[k] for p in per)
            for k in per[0] if k != "hit_rate"}
        agg["hit_rate"] = agg["hits"] / max(1, agg["queries"])
        agg["per_replica"] = per
        return agg

    def fresh_state(self) -> None:
        """Reset every replica for a new serving phase.

        Calls each scheduler's ``fresh_state()`` — engine state, page
        pool and radix index are rebuilt and the prefix/stat counters
        zeroed — and clears the router's own response and routing
        ledgers.  Request-id uniqueness is also reset (phases are
        independent).
        """
        for rep in self.replicas:
            rep.scheduler.fresh_state()
            rep.routed = 0
        self.responses = {}
        self._replica_of = {}
        self.routing = {k: 0 for k in self.routing}
        self._rr = 0
        self._seq = 0
