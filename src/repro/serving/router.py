"""Multi-replica request router with preamble-affinity placement.

:class:`ReplicaRouter` fronts N independent engine/scheduler replicas
(:mod:`repro.serving.replica`) and decides, per request, which replica's
queue it joins.  Three policies:

``affinity`` (default)
    Keep requests that share a prompt preamble on the same replica, so
    that replica's radix prefix cache serves the preamble's KV pages to
    all of them.  Placement is two-tier: first the prompt is matched
    against every replica's radix index and the replica with the
    *longest* cached prefix wins (true longest-preamble affinity —
    pages already live there); on a miss everywhere the request falls
    back to a deterministic hash of its first full page-size token chunk
    (the page-aligned preamble — stable across requests that share a
    preamble, whatever their total length), so a burst of same-preamble
    requests submitted before any page is published still lands on one
    replica.  Prompts shorter than one full page (nothing shareable) and
    placements that would push a replica's load more than ``skew``
    requests past the least-loaded replica fall back to least-loaded.

``round_robin``
    Cycle replicas in submission order (the locality-blind baseline the
    benchmark compares affinity against).

``least_loaded``
    Always the replica with the fewest outstanding requests
    (queued + live slots); ties break to the lowest replica index.

The hash tier comes in two flavours (``hash_tier``): ``mod`` — blake2b
of the chunk mod N — and ``rendezvous`` — highest-random-weight hashing,
where growing the fleet from N to N+1 replicas remaps only ~1/(N+1) of
preamble groups (every moved group moves *to* the new replica), so a
scale-out does not cold-start every replica's prefix cache.

The fleet is driven either sequentially (``threaded=False`` — ``step``
loops over replicas in host code) or, by default, by a thread per
replica: each thread owns its replica's scheduler/engine/state outright
(replicas share no device state, so threads never contend on anything
but the router's response ledger), drains a thread-safe submit inbox,
waits out idle gaps on a condition variable instead of a sleep poll, and
pushes finished :class:`Response` objects to the router under a lock.
Per-replica rng chains are seeded by ``fold_in(fleet_key, index)``, so a
replica's key sequence is independent of peers and thread interleaving.

The router assembles id-keyed :class:`Response` objects across replicas
(out-of-order completion included) and aggregates ``prefix_stats()`` /
``EngineStats`` over the fleet.  Replicas share nothing, so per-replica
invariants (page conservation, one-live-state) hold independently —
the hypothesis property test drives routed admissions against exactly
that.
"""
from __future__ import annotations

import hashlib
import threading
from typing import Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.serving.gsi_engine import EngineStats, merge_engine_stats
from repro.serving.replica import Replica, build_replicas
from repro.serving.scheduler import GSIScheduler, Response

POLICIES = ("affinity", "round_robin", "least_loaded")
HASH_TIERS = ("mod", "rendezvous")


def _chunk_bytes(tokens) -> bytes:
    return np.ascontiguousarray(np.asarray(tokens, np.int32)).tobytes()


def preamble_hash(tokens, num_replicas: int) -> int:
    """Deterministic replica index for a token chunk (blake2b mod N).

    Stable across processes (unlike builtin ``hash``, which is salted),
    so affinity placement is reproducible run to run — the property
    tests and the throughput ``--check`` both rely on that.
    """
    digest = hashlib.blake2b(_chunk_bytes(tokens), digest_size=8).digest()
    return int.from_bytes(digest, "big") % num_replicas


def preamble_rendezvous(tokens, num_replicas: int) -> int:
    """Rendezvous (highest-random-weight) replica index for a chunk.

    Each replica's weight is a blake2b over (chunk, replica index); the
    chunk goes to the max-weight replica.  Because the N existing weights
    are unchanged when replica N+1 is added, a chunk moves on scale-out
    iff the *new* replica wins — so only ~1/(N+1) of preamble groups
    remap, and every moved group moves to the new replica (bounded
    movement; ``mod`` reshuffles ~N/(N+1) of them).
    """
    data = _chunk_bytes(tokens)
    best, best_w = 0, b""
    for i in range(num_replicas):
        w = hashlib.blake2b(data + i.to_bytes(4, "big"),
                            digest_size=8).digest()
        if w > best_w:
            best, best_w = i, w
    return best


class ReplicaRouter:
    """Route requests across N independent serving replicas.

    Parameters
    ----------
    engines:   one built :class:`GSIServingEngine` per replica (distinct
               objects — a paged engine backs one live state).
    capacity:  scheduler slots *per replica*.
    policy:    ``affinity`` | ``round_robin`` | ``least_loaded``.
    skew:      affinity-only load guard: if the affine replica's load
               exceeds the least-loaded replica's by more than ``skew``
               requests, route least-loaded instead (None disables the
               guard — pure affinity, used by deterministic checks).
    hash_tier: ``mod`` (blake2b mod N) or ``rendezvous`` (HRW; adding a
               replica remaps only ~1/N of preamble groups).
    cache_aware: enable cache-aware admission ordering inside each
               replica (queued requests with live radix matches first).
    sync:      forwarded to each replica scheduler — False gives every
               replica the pipelined (one-ticket-in-flight) decode loop.
    threaded:  drive ``run`` with one thread per replica (the fleet
               loop); False falls back to the sequential host loop.
               ``step`` is always the sequential single-step API.
    continuous / prompt_pad_len / collect_stats: forwarded to each
               replica's :class:`GSIScheduler`.
    """

    def __init__(self, engines, *, capacity: int,
                 policy: str = "affinity", skew: Optional[int] = 4,
                 hash_tier: str = "mod",
                 continuous: bool = True, prompt_pad_len: int = 0,
                 collect_stats: bool = False, cache_aware: bool = True,
                 sync: bool = True, threaded: bool = True,
                 chunk_tokens: int = 0):
        """Build one replica (engine + scheduler) per engine given."""
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}; "
                             f"choose from {POLICIES}")
        if hash_tier not in HASH_TIERS:
            raise ValueError(f"unknown hash tier {hash_tier!r}; "
                             f"choose from {HASH_TIERS}")
        # a fleet must be storage-homogeneous: affinity routing assumes a
        # request produces the same KV pages whichever replica serves it
        dtypes = {getattr(e, "kv_dtype", None) for e in engines}
        if len(dtypes) > 1:
            raise ValueError(f"replicas disagree on kv_dtype: "
                             f"{sorted(map(str, dtypes))}")
        self.kv_dtype: Optional[str] = next(iter(dtypes), None)
        # ... and mesh-homogeneous: all replicas sharded the same way
        # (tensor-parallel degree changes per-replica capacity and
        # latency, which would skew every load-balancing policy), over
        # *disjoint* submeshes (the thread-per-replica loop drives them
        # concurrently; a shared device would interleave collectives).
        meshes = [getattr(e, "mesh", None) for e in engines]
        shapes = {None if m is None else
                  (tuple(m.devices.shape), tuple(m.axis_names))
                  for m in meshes}
        if len(shapes) > 1:
            raise ValueError(
                f"replicas disagree on mesh shape: {sorted(map(str, shapes))}"
                " — carve one submesh per replica with the same "
                "(data, model) shape (launch.mesh.carve_submeshes)")
        seen: set = set()
        for m in meshes:
            if m is None:
                continue
            devs = {d.id for d in m.devices.flat}
            if devs & seen:
                raise ValueError(
                    "replica submeshes overlap on device id(s) "
                    f"{sorted(devs & seen)}; each replica needs its own "
                    "disjoint device slice")
            seen |= devs
        self.tp: int = max((getattr(e, "tp", 1) or 1) for e in engines) \
            if engines else 1
        # kept so add_replica() can build a scale-out replica's scheduler
        # with exactly the fleet's settings
        self._sched_kwargs = dict(
            capacity=capacity, continuous=continuous,
            prompt_pad_len=prompt_pad_len, collect_stats=collect_stats,
            cache_aware=cache_aware, sync=sync,
            chunk_tokens=chunk_tokens)
        self.replicas: List[Replica] = build_replicas(
            engines, **self._sched_kwargs)
        self.policy = policy
        self.skew = skew
        self.hash_tier = hash_tier
        self.capacity = capacity
        self.threaded = threaded
        self.responses: Dict[str, Response] = {}
        self.routing = {"affinity_matched": 0, "affinity_hashed": 0,
                        "fallback_load": 0}
        self._replica_of: Dict[str, int] = {}
        self._rr = 0
        self._seq = 0
        # fleet-loop plumbing: responses ledger lock + drain signal;
        # a replica thread that dies parks its exception here so run()
        # can abort and re-raise instead of waiting forever
        self._lock = threading.Lock()
        self._fleet_cv = threading.Condition()
        self._fleet_error: Optional[BaseException] = None

    # ------------------------------------------------------------------
    # Placement
    # ------------------------------------------------------------------
    @property
    def num_replicas(self) -> int:
        """Number of replicas in the fleet."""
        return len(self.replicas)

    def loads(self) -> List[int]:
        """Outstanding requests (inbox + queued + live) per replica."""
        return [r.load for r in self.replicas]

    def _least_loaded(self, loads: Sequence[int]) -> int:
        return int(np.argmin(loads))          # ties -> lowest index

    def route(self, prompt) -> int:
        """Pick the replica index for ``prompt`` under the policy.

        Pure placement — no queue mutation; ``submit`` calls this and
        then hands the request to the chosen replica.
        """
        if self.policy == "round_robin":
            i = self._rr
            self._rr = (self._rr + 1) % self.num_replicas
            return i
        loads = self.loads()
        if self.policy == "least_loaded":
            return self._least_loaded(loads)
        return self._route_affinity(np.asarray(prompt,
                                               np.int32).reshape(-1),
                                    loads)

    def _hash_replica(self, chunk) -> int:
        """Tier-2 placement: hash the page-aligned preamble chunk."""
        if self.hash_tier == "rendezvous":
            return preamble_rendezvous(chunk, self.num_replicas)
        return preamble_hash(chunk, self.num_replicas)

    def _route_affinity(self, prompt: np.ndarray,
                        loads: Sequence[int]) -> int:
        """Longest-preamble affinity with hash seeding and a skew guard.

        Tier 1: the replica whose radix index holds the longest cached
        prefix of ``prompt`` (ties break to the less-loaded replica).
        Tier 2 (no replica has a match): hash the first full page-size
        chunk of the prompt (``hash_tier``).  Tier 3 (prompt too short
        to ever share a page): least-loaded.  Finally the skew guard may
        override a placement that would unbalance the fleet.
        """
        best, best_len = None, 0
        for rep in self.replicas:
            _, matched = rep.engine.match_prefix(prompt)
            if matched > best_len or (
                    matched == best_len and matched > 0
                    and loads[rep.index] < loads[best]):
                best, best_len = rep.index, matched
        if best is not None:
            tier = "affinity_matched"
        else:
            page_size = self.replicas[0].engine.page_size
            if prompt.size - 1 >= page_size:
                best = self._hash_replica(prompt[:page_size])
                tier = "affinity_hashed"
            else:
                self.routing["fallback_load"] += 1
                return self._least_loaded(loads)
        if self.skew is not None and \
                loads[best] - min(loads) > self.skew:
            # exactly one counter per request: a skew override is
            # reported as the fallback it actually was, not as affinity
            self.routing["fallback_load"] += 1
            return self._least_loaded(loads)
        self.routing[tier] += 1
        return best

    # ------------------------------------------------------------------
    # Scale-out with cache migration
    # ------------------------------------------------------------------
    def add_replica(self, engine) -> Dict[str, int]:
        """Grow the fleet by one replica, migrating hot cache to it.

        The new engine joins as replica N with the fleet's scheduler
        settings.  Then, for every preamble group (root radix chunk) on
        every existing replica, the hash tier is re-evaluated over the
        grown fleet: a group that now maps elsewhere has its cached
        subtree *pushed* through the snapshot codec
        (:func:`repro.serving.snapshot.snapshot_state` restricted to
        that group) into the destination's state, and is then dropped
        from the source (``PagePool.forget``) so tier-1 longest-match
        affinity follows the pages instead of sticking to the stale
        copy.  The destination serves the group's next request from
        spliced pages — no re-prefill.

        Under ``rendezvous`` hashing only ~1/(N+1) of groups remap and
        every one of them lands on the new replica (bounded movement);
        under ``mod`` most groups move, which is exactly the cold-start
        this method exists to avoid — prefer ``hash_tier="rendezvous"``
        for elastic fleets.  Groups whose root page is pinned by a live
        slot are skipped (their pages belong to in-flight requests).
        Call between runs, not while a threaded ``run`` is draining —
        the migration touches source and destination states directly.

        Returns ``{"groups_moved": g, "pages_moved": p}``.
        """
        from repro.serving.snapshot import snapshot_state

        if any(engine is rep.engine for rep in self.replicas):
            raise ValueError(
                "replicas must not share engine objects: a paged engine "
                "backs one live state at a time; build a fresh engine "
                "for the new replica")
        if getattr(engine, "kv_dtype", None) != self.kv_dtype:
            raise ValueError(
                f"new replica kv_dtype {getattr(engine, 'kv_dtype', None)!r}"
                f" != fleet kv_dtype {self.kv_dtype!r}")
        mesh = getattr(engine, "mesh", None)
        fleet_meshes = [getattr(rep.engine, "mesh", None)
                        for rep in self.replicas]
        shape = None if mesh is None else \
            (tuple(mesh.devices.shape), tuple(mesh.axis_names))
        fleet_shapes = {None if m is None else
                        (tuple(m.devices.shape), tuple(m.axis_names))
                        for m in fleet_meshes}
        if fleet_shapes and {shape} != fleet_shapes:
            raise ValueError(
                f"new replica mesh shape {shape} does not match the "
                f"fleet's {sorted(map(str, fleet_shapes))}")
        if mesh is not None:
            taken = {d.id for m in fleet_meshes if m is not None
                     for d in m.devices.flat}
            devs = {d.id for d in mesh.devices.flat}
            if devs & taken:
                raise ValueError(
                    "new replica submesh overlaps the fleet on device "
                    f"id(s) {sorted(devs & taken)}")
        rep = Replica(len(self.replicas),
                      GSIScheduler(engine, **self._sched_kwargs))
        self.replicas.append(rep)
        groups_moved = 0
        pages_moved = 0
        for src in self.replicas[:-1]:
            pager = src.engine.pager
            if pager is None or pager.index is None:
                continue
            for chunk in pager.index.groups():
                dest = self._hash_replica(np.asarray(chunk, np.int32))
                if dest == src.index:
                    continue
                node = pager.index.root.children.get(chunk)
                if node is None or node.page not in pager.cached:
                    continue          # pinned by a live slot: stays put
                snap = snapshot_state(src.engine, src.scheduler.state,
                                      roots=[chunk])
                if snap["pages"].size:
                    dst = self.replicas[dest]
                    dst.scheduler.state = dst.engine.load_cache(
                        dst.scheduler.state, snap)
                pages_moved += pager.forget(node.page)
                groups_moved += 1
        return {"groups_moved": groups_moved, "pages_moved": pages_moved}

    # ------------------------------------------------------------------
    # Submission / stepping
    # ------------------------------------------------------------------
    def submit(self, prompt, *, request_id: Optional[str] = None,
               max_steps: Optional[int] = None,
               arrival_time: float = 0.0, priority: int = 0,
               deadline_s: Optional[float] = None, stream=None) -> str:
        """Route a prompt to a replica queue; returns the request id.

        Ids are unique fleet-wide (router-assigned ``req-N`` by default;
        caller-provided ids are checked against every replica).  The
        hand-off goes through the replica's thread-safe inbox, so
        submitting while a threaded ``run`` is draining is safe.
        ``priority``/``deadline_s``/``stream`` pass straight through to
        the replica scheduler (see ``GSIScheduler.submit``).
        """
        if request_id is None:
            # skip ids a caller already used explicitly — a collision
            # would silently overwrite the other request's Response
            while f"req-{self._seq}" in self._replica_of:
                self._seq += 1
            request_id = f"req-{self._seq}"
        elif request_id in self._replica_of:
            raise ValueError(f"request id {request_id!r} already routed "
                             f"to replica {self._replica_of[request_id]}")
        self._seq += 1
        idx = self.route(prompt)
        self.replicas[idx].submit(prompt, request_id=request_id,
                                  max_steps=max_steps,
                                  arrival_time=arrival_time,
                                  priority=priority,
                                  deadline_s=deadline_s, stream=stream)
        self._replica_of[request_id] = idx
        with self._fleet_cv:
            self._fleet_cv.notify_all()   # wake a sequential idle wait
        return request_id

    def replica_of(self, request_id: str) -> int:
        """The replica index a submitted request was routed to."""
        return self._replica_of[request_id]

    def step(self, rng) -> List[Response]:
        """Step every replica once; returns the responses finished now.

        Sequential single-step API (testing / manual driving): each
        replica gets an independent key pair split from ``rng``, so a
        replica's rng stream never depends on how many peers it has or
        on what they decode.  Idle replicas skip their engine step.
        """
        keys = jax.random.split(rng, 2 * self.num_replicas)
        finished: List[Response] = []
        for rep in self.replicas:
            k1, k2 = keys[2 * rep.index], keys[2 * rep.index + 1]
            for resp in rep.step(k1, k2):
                self.responses[resp.request_id] = resp
                finished.append(resp)
        return finished

    # ------------------------------------------------------------------
    # Fleet loop
    # ------------------------------------------------------------------
    def run(self, rng) -> Dict[str, Response]:
        """Drain every replica; returns id -> Response across the fleet.

        ``threaded=True`` (default): one thread per replica drives that
        replica's scheduler until the whole fleet is drained — replicas
        decode concurrently, each on its own engine/state/pool, and the
        main thread waits on a condition variable (no sleep-polling).
        ``threaded=False``: the sequential host loop steps replicas one
        after another (the pre-fleet-loop behaviour, key schedule
        included).
        """
        if not self.threaded:
            return self._run_sequential(rng)
        for rep in self.replicas:
            rep.seed_rng(rng)
        self._fleet_error = None
        stop = threading.Event()
        threads = [threading.Thread(target=self._serve, args=(rep, stop),
                                    name=f"replica-{rep.index}",
                                    daemon=True)
                   for rep in self.replicas]
        for t in threads:
            t.start()
        try:
            with self._fleet_cv:
                while self._fleet_error is None and \
                        any(rep.has_work for rep in self.replicas):
                    # woken by replica threads on progress/idle/error;
                    # the timeout is a missed-notification safety net
                    self._fleet_cv.wait(timeout=0.2)
        finally:
            stop.set()
            for rep in self.replicas:
                with rep.cv:
                    rep.cv.notify_all()
            for t in threads:
                t.join()
        if self._fleet_error is not None:
            raise RuntimeError(
                "a replica fleet-loop thread failed; the run was "
                "aborted") from self._fleet_error
        return dict(self.responses)

    def _serve(self, rep: Replica, stop: threading.Event) -> None:
        """Fleet-loop body: drive one replica until the run is stopped.

        Only this thread touches the replica's scheduler/engine/state.
        Idle replicas park on their condition variable (woken by submit
        or stop); arrival gaps wait exactly the gap.  Finished responses
        are pushed to the router ledger under its lock.  Any exception
        is parked on the router (``run`` re-raises it) instead of
        silently killing the thread and hanging the fleet.
        """
        try:
            self._serve_loop(rep, stop)
        except BaseException as exc:                  # noqa: BLE001
            with self._fleet_cv:
                if self._fleet_error is None:
                    self._fleet_error = exc
                self._fleet_cv.notify_all()

    def _serve_loop(self, rep: Replica, stop: threading.Event) -> None:
        """The actual per-replica drive loop (see ``_serve``)."""
        sched = rep.scheduler
        while True:
            if stop.is_set() and self._fleet_error is not None:
                return            # a peer died: abort, don't drain
            rep.drain_inbox()
            now = sched._now()
            busy = sched.pool.num_live > 0 or sched.has_pending
            ready = bool(sched.queue) and \
                sched.queue[0].arrival_time <= now
            if not busy and not ready:
                nxt = rep.next_arrival()
                if nxt is None:
                    # fully drained: tell the fleet waiter, then park
                    with self._fleet_cv:
                        self._fleet_cv.notify_all()
                    with rep.cv:
                        if stop.is_set():
                            return
                        if not rep.inbox:
                            rep.cv.wait(timeout=0.05)
                    continue
                wait = nxt - now
                if wait > 0:
                    with rep.cv:
                        if not rep.inbox and not stop.is_set():
                            rep.cv.wait(timeout=wait)
                continue
            k1, k2 = rep.next_keys()
            finished = sched.step(k1, k2)
            if finished:
                with self._lock:
                    for resp in finished:
                        self.responses[resp.request_id] = resp
                with self._fleet_cv:
                    self._fleet_cv.notify_all()

    def _run_sequential(self, rng) -> Dict[str, Response]:
        """Sequential fleet drain (``threaded=False``).

        Mirrors ``GSIScheduler.run``: while any replica holds work, step
        the fleet; when every live slot is drained and the earliest
        queued arrival is still in the future, wait out exactly the gap
        on the fleet condition variable (woken early by new submits).
        """
        while any(rep.has_work for rep in self.replicas):
            busy = any(rep.scheduler.pool.num_live
                       or rep.scheduler.has_pending
                       for rep in self.replicas)
            if not busy:
                waits = [rep.next_arrival() - rep.scheduler._now()
                         for rep in self.replicas
                         if rep.next_arrival() is not None]
                wait = min(waits) if waits else 0.0
                if wait > 0:
                    with self._fleet_cv:
                        self._fleet_cv.wait(timeout=wait)
                    continue
            rng, k = jax.random.split(rng)
            self.step(k)
        return dict(self.responses)

    # ------------------------------------------------------------------
    # Fleet-level stats
    # ------------------------------------------------------------------
    @property
    def engine_steps(self) -> int:
        """Total decode steps across the fleet (sum over replicas).

        Replicas step concurrently in the threaded fleet loop, so the
        wall-clock proxy is ``max`` — see ``engine_steps_max``.
        """
        return sum(rep.scheduler.engine_steps for rep in self.replicas)

    @property
    def engine_steps_max(self) -> int:
        """Decode steps of the busiest replica (parallel-time proxy)."""
        return max(rep.scheduler.engine_steps for rep in self.replicas)

    @property
    def stats(self) -> EngineStats:
        """Aggregate EngineStats over the fleet (counters summed,
        trace moments merged exactly, bounded trace lists concatenated).
        """
        return merge_engine_stats([rep.scheduler.stats
                                   for rep in self.replicas])

    def prefix_stats(self) -> Dict[str, object]:
        """Fleet-aggregate prefix-cache counters.

        Same scalar keys as ``GSIScheduler.prefix_stats()`` (counters
        summed, ``hit_rate`` recomputed from the sums) plus
        ``per_replica`` with each replica's own counters — per-replica
        hit-rates are how affinity quality is read.
        """
        per = [rep.scheduler.prefix_stats() for rep in self.replicas]
        agg: Dict[str, object] = {
            k: sum(p[k] for p in per)
            for k in per[0] if k != "hit_rate"}
        agg["hit_rate"] = agg["hits"] / max(1, agg["queries"])
        agg["per_replica"] = per
        return agg

    def pipeline_stats(self) -> Dict[str, object]:
        """Fleet-aggregate async-pipeline overlap counters.

        Scalar seconds sum across replicas, ``overlap_fraction`` is
        recomputed from the sums, and ``per_replica`` carries each
        replica's own ``GSIScheduler.pipeline_stats()``.
        """
        per = [rep.scheduler.pipeline_stats() for rep in self.replicas]
        agg: Dict[str, object] = {
            k: sum(p[k] for p in per)
            for k in per[0] if k not in ("sync", "overlap_fraction")}
        total = agg["overlap_host_s"] + agg["serial_host_s"]
        agg["overlap_fraction"] = \
            agg["overlap_host_s"] / total if total > 0 else 0.0
        agg["sync"] = per[0]["sync"]
        agg["per_replica"] = per
        return agg

    def fresh_state(self) -> None:
        """Reset every replica for a new serving phase.

        Calls each scheduler's ``fresh_state()`` — engine state, page
        pool and radix index are rebuilt and the prefix/stat counters
        zeroed — clears each replica's inbox and rng chain, and clears
        the router's own response and routing ledgers.  Request-id
        uniqueness is also reset (phases are independent).
        """
        for rep in self.replicas:
            with rep.cv:
                rep.inbox.clear()
            rep.scheduler.fresh_state()
            rep.routed = 0
            rep._rng = None
        self.responses = {}
        self._replica_of = {}
        self.routing = {k: 0 for k in self.routing}
        self._rr = 0
        self._seq = 0
        self._fleet_error = None
