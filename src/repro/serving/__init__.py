from repro.serving.engine import (branch_cache, branch_pages,  # noqa: F401
                                  paged_view, repeat_cache,
                                  reset_cache_rows, take_candidates)
from repro.serving.gsi_engine import (GSIServingEngine, EngineStats,  # noqa: F401
                                      StepResult)
from repro.serving.latency import LatencyModel, HW_V5E  # noqa: F401
from repro.serving.pages import (PagePool, RadixIndex,  # noqa: F401
                                 pages_for)
from repro.serving.scheduler import (GSIScheduler, Request,  # noqa: F401
                                     Response)
from repro.serving.slots import (SlotPool, pack_prompts,  # noqa: F401
                                 pack_tails)
