"""Serving layer: engine, slots, pages, radix cache, scheduler, router.

Public surface of the GSI serving stack, bottom-up:

- :class:`GSIServingEngine` — the three-model (draft/target/PRM) decode
  engine; dense or paged KV layout, optional radix prefix cache.
- :class:`SlotPool` / :class:`PagePool` / :class:`RadixIndex` — host-side
  ledgers for slots, refcounted pages and content-addressed prefixes.
- :class:`GSIScheduler` — continuous-batching request scheduler over one
  engine (queue, admission control, response assembly).
- :class:`Replica` / :class:`ReplicaRouter` — data-parallel scale-out:
  N independent engine+scheduler replicas behind a preamble-affinity
  router.

See ``docs/ARCHITECTURE.md`` for the layer map and lifecycles and
``docs/SERVING.md`` for the operator guide.
"""
from repro.serving.engine import (branch_cache, branch_pages,  # noqa: F401
                                  paged_view, repeat_cache,
                                  reset_cache_rows, take_candidates)
from repro.serving.gsi_engine import (GSIServingEngine, EngineStats,  # noqa: F401
                                      StepResult, StepTicket,
                                      merge_engine_stats)
from repro.serving.latency import LatencyModel, HW_V5E  # noqa: F401
from repro.serving.pages import (PagePool, RadixIndex,  # noqa: F401
                                 pages_for)
from repro.serving.quant import (quantize_draft_params,  # noqa: F401
                                 quantized_fraction)
from repro.serving.replica import Replica, build_replicas  # noqa: F401
from repro.serving.router import (ReplicaRouter, POLICIES,  # noqa: F401
                                  HASH_TIERS, preamble_hash,
                                  preamble_rendezvous)
from repro.serving.scheduler import (GSIScheduler, Request,  # noqa: F401
                                     Response, StreamEvent, TokenStream)
from repro.serving.snapshot import (index_records,  # noqa: F401
                                    load_snapshot, restore_records,
                                    restore_state, save_snapshot,
                                    snapshot_state)
from repro.serving.slots import (SlotPool, pack_prompts,  # noqa: F401
                                 pack_tails)
