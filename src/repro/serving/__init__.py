from repro.serving.engine import repeat_cache, take_candidates  # noqa: F401
from repro.serving.gsi_engine import GSIServingEngine, EngineStats  # noqa: F401
from repro.serving.latency import LatencyModel, HW_V5E  # noqa: F401
