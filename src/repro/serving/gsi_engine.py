"""The GSI three-model serving engine (Algorithm 1, end to end).

Co-locates draft pi_S, target pi_B and the PRM on one mesh and runs the
step-level loop:

  draft phase   — n scratch copies of the committed draft cache; sample n
                  candidate steps; score them under pi_B (one parallel pass,
                  ``score_and_append`` on a scratch target cache) and under
                  the PRM; tilted-S-BoN select + threshold (core.gsi).
  target phase  — on rejection: n candidate steps sampled from pi_B, PRM
                  rewards, raw-reward S-BoN (lines 9-12).
  commit        — append the chosen step to all three committed caches.

The same engine, re-parameterized, implements every baseline of the paper:
RSD (raw rewards + threshold), S-BoN(draft), S-BoN(base), and the
"GSI w/o rejection" ablation.  Host-side loop + jitted phases; per-request
divergence handled with live-masking (PAD) rather than re-batching.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import GSIConfig, ModelConfig
from repro.core import gsi_select, rsd_select, soft_bon_select
from repro.models import build_model
from repro.sampling import sample_steps, score_and_append
from repro.serving.engine import (expand_requests, fold_candidates,
                                  repeat_cache, reset_cache_rows,
                                  take_candidates, take_per_request)

PAD = 0


class StepResult(NamedTuple):
    """Host-side outcome of one engine decode step (all numpy, (B,...))."""
    chosen: np.ndarray       # (B, L) committed step tokens (PAD-padded)
    done_prev: np.ndarray    # (B,) slot was already done before this step
    eos: np.ndarray          # (B,) step emitted EOS
    failed: np.ndarray       # (B,) B.2 early-stop: all draft rewards low
    accept: np.ndarray       # (B,) draft step accepted (True in sbon_b)


@dataclass
class EngineStats:
    steps: int = 0
    accepted: int = 0
    decisions: int = 0
    draft_tokens: int = 0
    target_tokens: int = 0
    requests_finished: int = 0
    tilted_rewards: list = field(default_factory=list)
    raw_rewards: list = field(default_factory=list)
    logp_ratio: list = field(default_factory=list)   # log pi_B - log pi_S

    @property
    def accept_rate(self) -> float:
        return self.accepted / max(1, self.decisions)


class GSIServingEngine:
    """mode: gsi | gsi_norej | rsd | sbon_s | sbon_b."""

    def __init__(self, draft_cfg: ModelConfig, target_cfg: ModelConfig,
                 prm_cfg: ModelConfig, params_s, params_b, params_p,
                 gcfg: GSIConfig, *, mode: str = "gsi",
                 rsd_threshold: float = 0.7, max_seq: int = 512,
                 shared_scoring: bool = False):
        assert prm_cfg.reward_head
        self.mode = mode
        self.gcfg = gcfg
        self.rsd_threshold = rsd_threshold
        self.max_seq = max_seq
        # beyond-paper: score candidates against ONE shared cache instead of
        # n scratch copies (models/scoring.py); identical math, far less HBM.
        self.shared_scoring = shared_scoring
        self.draft = build_model(draft_cfg)
        self.target = build_model(target_cfg)
        self.prm = build_model(prm_cfg)
        self.params = (params_s, params_b, params_p)
        self._jit_draft_phase = jax.jit(self._draft_phase)
        self._jit_target_phase = jax.jit(self._target_phase)
        self._jit_commit = jax.jit(self._commit)
        self._jit_admit = jax.jit(self._admit)

    # ------------------------------------------------------------------
    # State
    # ------------------------------------------------------------------
    def _fresh_caches(self, batch: int):
        return {
            "S": self.draft.init_cache(batch, self.max_seq),
            "B": self.target.init_cache(batch, self.max_seq),
            "P": self.prm.init_cache(batch, self.max_seq),
        }

    def fresh_state(self, batch: int):
        """An all-free slot-pool state: every row is done/inert until a
        prompt is admitted into it (scheduler API)."""
        return {
            "caches": self._fresh_caches(batch),
            "pending": jnp.full((batch,), PAD, jnp.int32),
            "pos": jnp.zeros((batch,), jnp.int32),
            "done": jnp.ones((batch,), bool),
        }

    def init_state(self, prompts: np.ndarray):
        """prompts: (B, Lp) PAD-padded token array.

        All-PAD rows (padding a partial batch up to capacity) start done,
        so they never decode or hold up ``run``'s all-done early exit.
        """
        B = prompts.shape[0]
        state = {
            "caches": self._fresh_caches(B),
            "pending": jnp.asarray(prompts[:, 0], jnp.int32),
            "pos": jnp.zeros((B,), jnp.int32),
            "done": jnp.asarray((np.asarray(prompts) == PAD).all(axis=1)),
        }
        if prompts.shape[1] > 1:
            state = self._jit_commit(state, jnp.asarray(prompts[:, 1:],
                                                        jnp.int32))
        return state

    # ------------------------------------------------------------------
    # Jitted phases
    # ------------------------------------------------------------------
    def _commit(self, state, step_tokens, row_live=None):
        """Append step_tokens (B,L) to the three committed caches."""
        ps, pb, pp = self.params
        caches = state["caches"]
        new = {}
        _, new["S"], pos = score_and_append(
            self.draft, ps, caches["S"], state["pending"], state["pos"],
            step_tokens, row_live=row_live)
        _, new["B"], _ = score_and_append(
            self.target, pb, caches["B"], state["pending"], state["pos"],
            step_tokens, row_live=row_live)
        _, new["P"], _, _ = score_and_append(
            self.prm, pp, caches["P"], state["pending"], state["pos"],
            step_tokens, return_rewards=True, row_live=row_live)
        length = jnp.sum(step_tokens != PAD, axis=1)
        if row_live is not None:
            length = jnp.where(row_live, length, 0)
        pending = jnp.where(
            length > 0,
            jnp.take_along_axis(
                step_tokens, jnp.maximum(length - 1, 0)[:, None],
                axis=1)[:, 0],
            state["pending"])
        return {"caches": new, "pending": pending, "pos": pos,
                "done": state["done"]}

    def _admit(self, state, admit_mask, prompts):
        """Prefill prompts (B,Lp; PAD-padded) into the slots where
        ``admit_mask`` is True; every other slot passes through untouched.

        Admitted rows are zeroed (stale recurrent state / ring buffers from
        the previous occupant), bookkeeping is reset to the engine invariant
        (cache holds prompt[:-1], pending = prompt[-1]) and the prompt tail
        is teacher-forced through all three models via the regular commit
        path with ``row_live`` masking.
        """
        caches = reset_cache_rows(state["caches"], admit_mask)
        state = {
            "caches": caches,
            "pending": jnp.where(admit_mask, prompts[:, 0],
                                 state["pending"]),
            "pos": jnp.where(admit_mask, 0, state["pos"]),
            "done": jnp.where(admit_mask, False, state["done"]),
        }
        return self._commit(state, prompts[:, 1:], row_live=admit_mask)

    def _draft_phase(self, state, rng):
        """Sample n draft candidates; score with target + PRM."""
        g = self.gcfg
        n = g.n
        ps, pb, pp = self.params
        k1, k2 = jax.random.split(rng)
        pend = expand_requests(state["pending"], n)
        pos = expand_requests(state["pos"], n)
        done = expand_requests(state["done"], n)

        scratch_s = repeat_cache(state["caches"]["S"], n)
        steps = sample_steps(
            self.draft, ps, scratch_s, pend, pos, k1,
            max_tokens=g.max_step_tokens, sep_token=g.sep_token_id,
            eos_token=g.eos_token_id, temperature=g.temperature,
            top_p=g.top_p, already_done=done)

        cands = fold_candidates(steps.tokens, n)             # (B,n,L)
        # PRM rewards (always needed)
        if self.shared_scoring:
            from repro.models.scoring import score_candidates
            _, rewards = score_candidates(
                self.prm, pp, state["caches"]["P"], state["pending"],
                state["pos"], cands, return_rewards=True)
        else:
            scratch_p = repeat_cache(state["caches"]["P"], n)
            _, _, _, rewards_flat = score_and_append(
                self.prm, pp, scratch_p, pend, pos, steps.tokens,
                return_rewards=True)
            rewards = fold_candidates(rewards_flat, n)

        out = {
            "cands": cands,
            "logp_S": fold_candidates(steps.logprob, n),     # (B,n)
            "rewards": rewards,
            "rng": k2,
        }
        if self.mode in ("gsi", "gsi_norej"):
            if self.shared_scoring:
                from repro.models.scoring import score_candidates
                out["logp_B"] = score_candidates(
                    self.target, pb, state["caches"]["B"],
                    state["pending"], state["pos"], cands)
            else:
                scratch_b = repeat_cache(state["caches"]["B"], n)
                logp_B, _, _ = score_and_append(
                    self.target, pb, scratch_b, pend, pos, steps.tokens)
                out["logp_B"] = fold_candidates(logp_B, n)
            dec = gsi_select(k2, out["rewards"], out["logp_B"],
                             out["logp_S"], beta=g.beta,
                             threshold_u=g.threshold_u)
            accept = dec.accept if (self.mode == "gsi" and g.use_rejection) \
                else jnp.ones_like(dec.accept)
            out.update(index=dec.index, accept=accept,
                       selected=dec.selected_tilted, tilted=dec.tilted)
        elif self.mode == "rsd":
            dec = rsd_select(k2, out["rewards"], beta=g.beta,
                             threshold=self.rsd_threshold)
            out.update(index=dec.index, accept=dec.accept,
                       selected=dec.selected_reward, tilted=out["rewards"])
        else:  # sbon_s: always accept the soft-BoN choice
            idx = soft_bon_select(k2, out["rewards"], g.beta)
            out.update(index=idx, accept=jnp.ones((idx.shape[0],), bool),
                       selected=take_per_request(out["rewards"], idx),
                       tilted=out["rewards"])
        out["chosen"] = take_candidates(out["cands"], out["index"])
        out["max_reward"] = jnp.max(out["rewards"], axis=-1)
        return out

    def _target_phase(self, state, rng):
        """S-BoN with the target model (rejection fallback / sbon_b)."""
        g = self.gcfg
        n = g.n_target or g.n
        _, pb, pp = self.params
        k1, k2 = jax.random.split(rng)
        pend = expand_requests(state["pending"], n)
        pos = expand_requests(state["pos"], n)
        done = expand_requests(state["done"], n)

        scratch_b = repeat_cache(state["caches"]["B"], n)
        steps = sample_steps(
            self.target, pb, scratch_b, pend, pos, k1,
            max_tokens=g.max_step_tokens, sep_token=g.sep_token_id,
            eos_token=g.eos_token_id, temperature=g.temperature,
            top_p=g.top_p, already_done=done)
        scratch_p = repeat_cache(state["caches"]["P"], n)
        _, _, _, rewards = score_and_append(
            self.prm, pp, scratch_p, pend, pos, steps.tokens,
            return_rewards=True)
        cands = fold_candidates(steps.tokens, n)
        r = fold_candidates(rewards, n)
        idx = soft_bon_select(k2, r, g.beta)
        return {"chosen": take_candidates(cands, idx),
                "rewards": r, "selected": take_per_request(r, idx)}

    # ------------------------------------------------------------------
    # Host loop
    # ------------------------------------------------------------------
    def step_decode(self, state, rng, rng_target=None, *,
                    stats: Optional[EngineStats] = None,
                    collect_stats: bool = False):
        """One engine step over the whole (fixed-size) batch.

        Runs the mode's phase(s) on every live slot (done slots are masked
        and stay inert), commits the chosen step to the three caches, and
        folds EOS / B.2 early-stop into ``state["done"]``.  Returns
        ``(state, StepResult)``; the caller (``run`` or the
        continuous-batching scheduler) owns response assembly.
        """
        g = self.gcfg
        B = int(state["done"].shape[0])
        if rng_target is None:
            rng, rng_target = jax.random.split(rng)
        if self.mode == "sbon_b":
            tp = self._jit_target_phase(state, rng)
            chosen = tp["chosen"]
            accept = np.ones((B,), bool)
            max_r = np.asarray(jnp.max(tp["rewards"], -1))
            if stats is not None:
                stats.target_tokens += int(
                    np.sum(np.asarray(chosen) != PAD)) * g.n
        else:
            dp = self._jit_draft_phase(state, rng)
            accept = np.asarray(dp["accept"])
            chosen = dp["chosen"]
            max_r = np.asarray(dp["max_reward"])
            if stats is not None:
                stats.draft_tokens += int(
                    np.sum(np.asarray(dp["cands"]) != PAD))
                if collect_stats:
                    stats.raw_rewards.append(np.asarray(dp["rewards"]))
                    if "logp_B" in dp:
                        stats.logp_ratio.append(
                            np.asarray(dp["logp_B"] - dp["logp_S"]))
                        stats.tilted_rewards.append(np.asarray(dp["tilted"]))
            if not accept.all():
                tp = self._jit_target_phase(state, rng_target)
                chosen = jnp.where(jnp.asarray(accept)[:, None],
                                   chosen, tp["chosen"])
                if stats is not None:
                    stats.target_tokens += int(
                        np.sum(np.asarray(tp["chosen"]) != PAD)) * g.n
            if stats is not None:
                live = ~np.asarray(state["done"])
                stats.decisions += int(live.sum())
                stats.accepted += int((accept & live).sum())

        # early stop (paper B.2): all draft rewards below min threshold
        failed = max_r < g.min_step_reward
        chosen_np = np.asarray(chosen)
        done_prev = np.asarray(state["done"])
        state = self._jit_commit(state, chosen)
        eos = np.asarray(jnp.any(chosen == g.eos_token_id, axis=1))
        new_done = done_prev | eos | (failed & ~done_prev)
        state["done"] = jnp.asarray(new_done)
        if stats is not None:
            stats.steps += 1
        return state, StepResult(chosen=chosen_np, done_prev=done_prev,
                                 eos=eos, failed=failed, accept=accept)

    def admit(self, state, admit_mask: np.ndarray, prompts: np.ndarray):
        """Scheduler API: prefill ``prompts`` (B,Lp) into masked slots."""
        return self._jit_admit(state, jnp.asarray(admit_mask, bool),
                               jnp.asarray(prompts, jnp.int32))

    def run(self, prompts: np.ndarray, rng, *,
            collect_stats: bool = True):
        """Fixed-batch run-to-completion: generate until EOS/max_steps.

        Returns (responses, stats); responses is a list of B lists of
        step-token arrays.  Kept as the simple batch API — the
        continuous-batching path lives in ``repro.serving.scheduler``.
        """
        g = self.gcfg
        B = prompts.shape[0]
        state = self.init_state(prompts)
        stats = EngineStats()
        responses = [[] for _ in range(B)]

        for it in range(g.max_steps):
            rng, k1, k2 = jax.random.split(rng, 3)
            state, res = self.step_decode(state, k1, k2, stats=stats,
                                          collect_stats=collect_stats)
            for b in range(B):
                if not res.done_prev[b]:
                    toks = res.chosen[b][res.chosen[b] != PAD]
                    responses[b].append(toks)
            if np.asarray(state["done"]).all():
                break
        stats.requests_finished = int(np.asarray(state["done"]).sum())
        return responses, stats
