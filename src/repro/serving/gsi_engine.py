"""The GSI three-model serving engine (Algorithm 1, end to end).

Co-locates draft pi_S, target pi_B and the PRM on one mesh and runs the
step-level loop:

  draft phase   — n scratch copies of the committed draft cache; sample n
                  candidate steps; score them under pi_B (one parallel pass,
                  ``score_and_append`` on a scratch target cache) and under
                  the PRM; tilted-S-BoN select + threshold (core.gsi).
  target phase  — on rejection: n candidate steps sampled from pi_B, PRM
                  rewards, raw-reward S-BoN (lines 9-12).
  commit        — append the chosen step to all three committed caches.

The same engine, re-parameterized, implements every baseline of the paper:
RSD (raw rewards + threshold), S-BoN(draft), S-BoN(base), and the
"GSI w/o rejection" ablation.  Host-side loop + jitted phases; per-request
divergence handled with live-masking (PAD) rather than re-batching.

The decode step is split into an asynchronous pipeline pair:
``dispatch_decode`` enqueues one whole engine step (draft phase, the
rejection-fallback target phase under a device-side ``lax.cond``, commit
and the done fold) as a single jitted computation and returns an in-flight
:class:`StepTicket` of device arrays without ever blocking the host, and
``materialize`` transfers the finished ticket to host numpy in one batched
``device_get``.  ``step_decode`` is exactly ``dispatch`` + ``materialize``
back-to-back, so the synchronous and pipelined schedulers run the same
compiled computation with the same rng keys — async == sync tokens
bit-identically, whatever the pipeline depth.
"""
from __future__ import annotations

import dataclasses
import threading
from dataclasses import dataclass, field
from typing import List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import GSIConfig, ModelConfig
from repro.core import gsi_select, rsd_select, soft_bon_select
from repro.distributed import tp as dtp
from repro.distributed.sharding import (as_shardings, mesh_axis_sizes,
                                        serve_state_pspecs,
                                        serve_target_pspecs)
from repro.kernels import quant
from repro.models import build_model
from repro.sampling import sample_steps, score_and_append
from repro.serving.engine import (branch_cache, branch_pages,
                                  expand_requests, fold_candidates,
                                  paged_view, repeat_cache, reset_cache_rows,
                                  take_candidates, take_per_request)
from repro.serving.pages import PagePool, RadixIndex, pages_for
from repro.serving.slots import pack_tails

PAD = 0


class StepResult(NamedTuple):
    """Host-side outcome of one engine decode step (all numpy, (B,...)).

    The trailing fields (``done`` onward) were added with the async
    pipeline: ``done``/``pos`` are the post-step bookkeeping a pipelined
    caller needs without touching device state, and the ``*_tokens`` /
    trace fields carry everything ``fold_step_stats`` records, so stats
    folding can be deferred off the dispatch critical path.
    """

    chosen: np.ndarray       # (B, L) committed step tokens (PAD-padded)
    done_prev: np.ndarray    # (B,) slot was already done before this step
    eos: np.ndarray          # (B,) step emitted EOS
    failed: np.ndarray       # (B,) B.2 early-stop: all draft rewards low
    accept: np.ndarray       # (B,) draft step accepted (True in sbon_b)
    done: Optional[np.ndarray] = None    # (B,) done *after* this step
    pos: Optional[np.ndarray] = None     # (B,) cache position after commit
    draft_tokens: int = 0    # non-PAD draft candidate tokens this step
    target_tokens: int = 0   # non-PAD target candidate tokens this step
    rewards: Optional[np.ndarray] = None      # (B, n) PRM rewards
    tilted: Optional[np.ndarray] = None       # (B, n) tilted rewards (gsi)
    logp_ratio: Optional[np.ndarray] = None   # (B, n) log pi_B - log pi_S


class StepTicket(NamedTuple):
    """An in-flight engine step: device arrays, no host synchronisation.

    Returned by ``dispatch_decode`` the moment the step is *enqueued* on
    the device stream; every field is a jax array (or None for fields the
    engine mode does not produce).  ``materialize`` turns a ticket into a
    :class:`StepResult` with one batched ``device_get`` — until then the
    host is free to run admission, harvest and page bookkeeping for
    neighbouring steps.  Tickets are immutable snapshots: releasing or
    re-admitting the slots they cover can never corrupt them.
    """

    chosen: jax.Array
    done_prev: jax.Array
    eos: jax.Array
    failed: jax.Array
    accept: jax.Array
    done: jax.Array
    pos: jax.Array
    draft_tokens: jax.Array          # () int32
    target_tokens: jax.Array         # () int32
    rewards: Optional[jax.Array]
    tilted: Optional[jax.Array]
    logp_ratio: Optional[jax.Array]


@dataclass
class EngineStats:
    """Serving counters + bounded trace arrays for one engine/scheduler.

    Scalar counters accumulate monotonically over a serving phase;
    ``record_trace`` keeps at most ``trace_limit`` arrays per trace while
    folding every array into exact running moments.  Fleet-level views
    (the replica router) combine per-replica instances with
    :func:`merge_engine_stats`.

    Instances are safe to update from concurrent replica threads: the
    compound read-modify-write paths (``bump`` for counters,
    ``record_trace`` for the moment fold) serialize on an internal lock,
    and ``merge_engine_stats`` snapshots each part under that lock.
    Plain attribute reads stay lock-free (single writes are atomic under
    the GIL; readers may observe a slightly stale counter, never a torn
    moment triple).
    """

    steps: int = 0
    accepted: int = 0
    decisions: int = 0
    draft_tokens: int = 0
    target_tokens: int = 0
    requests_finished: int = 0
    # prefix-cache counters (filled by the scheduler's admission path)
    prefix_queries: int = 0       # admissions that consulted the radix index
    prefix_hits: int = 0          # admissions with matched_len > 0
    prefix_hit_tokens: int = 0    # prompt tokens whose prefill was skipped
    prefix_pages_reused: int = 0  # cached/shared pages spliced into tables
    prefill_tokens: int = 0       # prompt tokens actually prefill-committed
    pages_evicted: int = 0        # cached pages evicted to admit (LRU)
    # decode-time publication: generated pages made matchable as they fill
    decode_pages_published: int = 0
    # SLO-aware scheduling counters (priority preemption + chunked prefill)
    preemptions: int = 0          # live slots paused for a higher priority
    resumes: int = 0              # paused requests re-admitted
    deadline_misses: int = 0      # finished requests past their deadline_s
    # largest prompt-token count committed by a single jitted admit/extend
    # call — the decode-stall proxy chunked prefill bounds (merged with max)
    prefill_commit_max: int = 0
    # per-step trace arrays are bounded: at most ``trace_limit`` arrays are
    # retained per trace, while running moments keep exact aggregate
    # mean/variance for arbitrarily long serving runs (collect_stats=True
    # under the scheduler must not grow memory without limit).
    trace_limit: int = 512
    tilted_rewards: list = field(default_factory=list)
    raw_rewards: list = field(default_factory=list)
    logp_ratio: list = field(default_factory=list)   # log pi_B - log pi_S
    moments: dict = field(default_factory=dict)      # name -> [n, mean, M2]
    # serializes compound updates from concurrent replica threads
    _lock: threading.Lock = field(default_factory=threading.Lock,
                                  repr=False, compare=False)

    @property
    def accept_rate(self) -> float:
        """Fraction of live-slot decisions that accepted the draft step."""
        return self.accepted / max(1, self.decisions)

    @property
    def prefix_hit_rate(self) -> float:
        """Fraction of admissions whose prompt matched cached pages."""
        return self.prefix_hits / max(1, self.prefix_queries)

    def bump(self, **deltas: int) -> None:
        """Atomically add ``deltas`` to the named scalar counters.

        The counter += paths run on engine and scheduler threads; routing
        them through one locked method keeps fleet totals exact when a
        stats object is (mis)shared across threads.
        """
        with self._lock:
            for name, d in deltas.items():
                setattr(self, name, getattr(self, name) + d)

    def record_trace(self, name: str, arr) -> None:
        """Append ``arr`` to the named trace (bounded) and fold it into
        the running moments (unbounded-safe Chan/Welford merge)."""
        arr = np.asarray(arr)
        x = arr.astype(np.float64).ravel()
        with self._lock:
            lst = getattr(self, name)
            if len(lst) < self.trace_limit:
                lst.append(arr)
            if x.size == 0:
                return
            n_a, mean_a, m2_a = self.moments.setdefault(name,
                                                        [0, 0.0, 0.0])
            n_b = x.size
            mean_b = float(x.mean())
            m2_b = float(((x - mean_b) ** 2).sum())
            n = n_a + n_b
            delta = mean_b - mean_a
            self.moments[name] = [
                n,
                mean_a + delta * n_b / n,
                m2_a + m2_b + delta * delta * n_a * n_b / n,
            ]

    def trace_mean(self, name: str) -> float:
        """Exact mean of every value ever recorded into ``name``."""
        return self.moments.get(name, [0, 0.0, 0.0])[1]

    def trace_var(self, name: str) -> float:
        """Exact population variance of the named trace."""
        n, _, m2 = self.moments.get(name, [0, 0.0, 0.0])
        return m2 / n if n else 0.0

    def trace_count(self, name: str) -> int:
        """Total values folded into the named trace's moments."""
        return self.moments.get(name, [0, 0.0, 0.0])[0]


def merge_engine_stats(parts: Sequence[EngineStats]) -> EngineStats:
    """Combine per-replica :class:`EngineStats` into one fleet view.

    Scalar counters sum; running moments merge exactly (the same
    Chan/Welford combine ``record_trace`` uses, so fleet-level
    ``trace_mean``/``trace_var`` equal what one scheduler would have
    measured); bounded trace lists concatenate up to ``trace_limit``.
    Each part is snapshotted under its own lock (replica threads may
    still be recording), and the inputs are left untouched.
    """
    out = EngineStats()
    if not parts:
        return out
    out.trace_limit = parts[0].trace_limit
    counters = ("steps", "accepted", "decisions", "draft_tokens",
                "target_tokens", "requests_finished", "prefix_queries",
                "prefix_hits", "prefix_hit_tokens", "prefix_pages_reused",
                "prefill_tokens", "pages_evicted",
                "decode_pages_published", "preemptions",
                "resumes", "deadline_misses")
    for p in parts:
        with p._lock:
            for f in counters:
                setattr(out, f, getattr(out, f) + getattr(p, f))
            # a max, not a sum: the fleet's worst single prefill commit
            out.prefill_commit_max = max(out.prefill_commit_max,
                                         p.prefill_commit_max)
            for trace in ("tilted_rewards", "raw_rewards", "logp_ratio"):
                lst = getattr(out, trace)
                lst.extend(getattr(p, trace)[:max(out.trace_limit
                                                  - len(lst), 0)])
            part_moments = {k: list(v) for k, v in p.moments.items()}
        for name, (n_b, mean_b, m2_b) in part_moments.items():
            n_a, mean_a, m2_a = out.moments.setdefault(name,
                                                       [0, 0.0, 0.0])
            n = n_a + n_b
            if n == 0:
                continue
            delta = mean_b - mean_a
            out.moments[name] = [
                n,
                mean_a + delta * n_b / n,
                m2_a + m2_b + delta * delta * n_a * n_b / n,
            ]
    return out


class GSIServingEngine:
    """mode: gsi | gsi_norej | rsd | sbon_s | sbon_b."""

    def __init__(self, draft_cfg: ModelConfig, target_cfg: ModelConfig,
                 prm_cfg: ModelConfig, params_s, params_b, params_p,
                 gcfg: GSIConfig, *, mode: str = "gsi",
                 rsd_threshold: float = 0.7, max_seq: int = 512,
                 shared_scoring: bool = False, paged: bool = False,
                 page_size: int = 16, num_pages: int = 0,
                 prefix_cache: bool = True, decode_publish: bool = True,
                 kv_dtype: Optional[str] = None,
                 quantize_draft: bool = False, mesh=None):
        """Build the three models and jit the engine's serving phases.

        ``mesh`` (a ``jax.sharding.Mesh`` with a ``model`` axis — usually
        one replica's submesh from ``launch.mesh.carve_submeshes``) turns
        on tensor-parallel serving: the *target* model's attention /
        FFN / vocab weights and its paged KV pools shard over the
        ``model`` axis (``distributed.sharding.serve_target_pspecs``,
        with per-group divisibility fallback to replication), while the
        draft and PRM stay replicated — speculation is local, only
        target scoring pays collectives.  Every jitted phase runs under
        one ``shard_map``, so draft phase + rejection-fallback target
        phase + commit remain ONE device-side step and the collectives
        overlap host admission through the same ``StepTicket``
        dispatch/materialize split; tokens stay bit-identical to the
        unsharded engine (collect-then-compute collectives, see
        ``repro.distributed.tp``).

        ``paged``/``page_size``/``num_pages`` select the paged KV layout
        (``num_pages=0`` sizes the pool to the dense capacity at state
        creation); ``prefix_cache`` enables the radix prefix index on
        paged engines (auto-disabled for recurrent/RWKV stacks).
        ``decode_publish`` additionally lets the scheduler publish a
        live slot's *generated* pages as its decode commits fill them
        (not just prompt pages at admission), so best-of-n retries and
        duplicate requests splice whole trajectories; publication is
        ordered after the on-stream commit exactly like ``admit``'s,
        and tokens are bit-identical with it on or off.

        ``kv_dtype`` picks the paged-pool storage format: ``None`` keeps
        the model activation dtype, ``"bf16"`` casts pages, ``"int8"`` /
        ``"fp8"`` store quantized codes with per-page per-kv-head scales
        (dequant fused into the paged-attention kernel).
        ``quantize_draft`` rounds the draft model's matmul weights
        through int8 at load (serving/quant.py).
        """
        assert prm_cfg.reward_head
        quant.validate_kv_dtype(kv_dtype)
        if kv_dtype is not None and not paged:
            raise ValueError("kv_dtype requires the paged KV layout "
                             "(pass paged=True)")
        self.kv_dtype = kv_dtype
        self.quantize_draft = bool(quantize_draft)
        self.mode = mode
        self.gcfg = gcfg
        self.rsd_threshold = rsd_threshold
        self.max_seq = max_seq
        # beyond-paper: score candidates against ONE shared cache instead of
        # n scratch copies (models/scoring.py); identical math, far less HBM.
        self.shared_scoring = shared_scoring
        # paged KV-cache: page pools + per-slot block table instead of dense
        # (B, max_seq) rows; candidate branching is copy-on-write page-table
        # aliasing (serving/engine.py) and slots draw pages from a host-side
        # allocator (serving/pages.py).  num_pages=0 sizes the pool to the
        # dense capacity (batch * nblk) at state creation.
        self.paged = paged
        self.page_size = page_size
        self.nblk = -(-max_seq // page_size)
        self.nmax = max(gcfg.n, gcfg.n_target or gcfg.n)
        # pages a single candidate branch can write in one reasoning step:
        # positions pos .. pos+max_step_tokens, worst-case page phase
        self.span = (page_size - 1 + gcfg.max_step_tokens) // page_size + 1
        self._num_pages = num_pages
        self.num_pages = 0            # set when a paged state is created
        self.pager: Optional[PagePool] = None
        self._trash = 0               # trash page id (last pool row)
        self._released: set = set()   # slots whose pt rows await trash-reset
        self._gen = 0                 # live-state generation (see fresh_state)
        self.draft = build_model(draft_cfg)
        self.target = build_model(target_cfg)
        self.prm = build_model(prm_cfg)
        if quantize_draft:
            # fake-quant at load: every draft matmul sees int8-rounded
            # weights, target/PRM weights stay untouched (serving/quant.py)
            from repro.serving.quant import quantize_draft_params
            params_s = quantize_draft_params(draft_cfg, params_s)
        self.params = (params_s, params_b, params_p)
        # cross-request prefix sharing (radix index over full committed
        # pages) is exact for pure-attention stacks: KV row i is a function
        # of tokens[0..i] only, and paged layers store absolute positions.
        # Recurrent/RWKV layers keep *dense per-slot* state that a spliced
        # page cannot carry, so sharing is auto-disabled there to preserve
        # bit-identical outputs.
        self.prefix_cache = bool(prefix_cache and paged
                                 and self._prefix_supported())
        self.decode_publish = bool(decode_publish and self.prefix_cache)
        self.mesh = mesh
        self.tp = 1
        self._tp_plan = {"attn": False, "mlp": False, "vocab": False}
        if mesh is not None:
            if "model" not in mesh.axis_names:
                raise ValueError("mesh mode needs a 'model' axis; got "
                                 f"axes {mesh.axis_names}")
            if shared_scoring:
                raise NotImplementedError(
                    "shared_scoring under a mesh is not supported yet "
                    "(score_candidates bypasses the tp unembed hook)")
            if target_cfg.num_experts:
                raise NotImplementedError(
                    "MoE targets under the serving mesh are not "
                    "supported yet (moe_ffn runs its own expert-parallel "
                    "shard_map, which cannot nest inside the engine's)")
            self.tp = mesh_axis_sizes(mesh).get("model", 1)
            # only stacks made of hooked layer kinds may shard; a
            # recurrent/rwkv/hybrid target serves replicated (mesh mode
            # still works — every collective hook simply no-ops).
            kinds = list(self.target.pattern) * self.target.repeats \
                + list(self.target.remainder)
            if all(k in ("full", "local", "cross", "enc") for k in kinds):
                self._tp_plan = dtp.tp_plan(target_cfg, self.tp)
            self._target_pspecs = serve_target_pspecs(
                self.target.param_specs(), mesh, plan=self._tp_plan)
            rep = jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec())
            params_s = jax.device_put(
                params_s, jax.tree.map(lambda _: rep, params_s))
            params_b = jax.device_put(
                params_b, as_shardings(self._target_pspecs, mesh))
            params_p = jax.device_put(
                params_p, jax.tree.map(lambda _: rep, params_p))
            self.params = (params_s, params_b, params_p)
            # the shard_map'd jits need the state *structure* (dense vs
            # paged, batch size) — built lazily by fresh_state()
            self._jit_step = self._jit_commit = None
            self._jit_admit = self._jit_extend = None
            self._jit_draft_phase = self._jit_target_phase = None
        else:
            self._jit_step = jax.jit(self._bind(self._decode_core))
            self._jit_commit = jax.jit(self._bind(self._commit))
            self._jit_admit = jax.jit(self._bind(self._admit))
            self._jit_extend = jax.jit(self._bind(self._extend))
            # standalone phase jits: not on the decode path (the fused
            # _decode_core is), kept for phase-level tests and debugging
            self._jit_draft_phase = jax.jit(self._bind(self._draft_phase))
            self._jit_target_phase = jax.jit(
                self._bind(self._target_phase))
        # host-side mirrors of per-slot bookkeeping, updated at admit /
        # materialize time: dispatch_decode assigns pages from these (a
        # read of the live device state would block on the in-flight
        # step and serialize the pipeline)
        self._known_pos = np.zeros((0,), np.int64)
        self._known_done = np.zeros((0,), bool)
        self._inflight_steps = 0      # dispatched but not yet materialized

    def _bind(self, phase):
        """Close a params-threading phase over ``self.params``.

        The phases take the three param trees as an explicit first
        argument (so the mesh mode can hand shard_map their shardings);
        the single-device jits bind the engine's own params here, which
        keeps the jitted attributes' call signature ``(state, ...)``.
        """
        def call(state, *extra):
            return phase(self.params, state, *extra)
        return call

    def _build_mesh_jits(self, state) -> None:
        """Compile the engine's phases as one ``shard_map`` each.

        Needs a structural ``state`` template (dense vs paged layout,
        batch size), so it runs from :meth:`fresh_state` rather than
        ``__init__``.  Every phase body traces inside the
        ``tensor_parallel`` context: the target's sharded leaves enter
        as local shards per ``serve_target_pspecs`` /
        ``serve_state_pspecs`` and the model hooks supply the
        collectives; draft/PRM params, rng keys, block tables and all
        control state stay replicated (spec ``P()``).
        """
        mesh = self.mesh
        R = jax.sharding.PartitionSpec()
        state_specs = serve_state_pspecs(
            state, mesh, shard_attn=self._tp_plan["attn"])

        def rep(tree):
            return jax.tree.map(lambda _: R, tree)

        pspecs = (rep(self.params[0]), self._target_pspecs,
                  rep(self.params[2]))

        def wrap(phase, n_extra, out_specs):
            def body(params, st, *extra):
                with dtp.tensor_parallel("model"):
                    return phase(params, st, *extra)
            sm = dtp.shard_map_compat(
                body, mesh=mesh,
                in_specs=(pspecs, state_specs) + (R,) * n_extra,
                out_specs=out_specs)
            jitted = jax.jit(sm)

            def call(st, *extra):
                return jitted(self.params, st, *extra)
            return call

        def commit(params, st, tokens):
            return self._commit(params, st, tokens)

        self._jit_step = wrap(self._decode_core, 2, (state_specs, R))
        self._jit_commit = wrap(commit, 1, state_specs)
        self._jit_admit = wrap(self._admit, 4, state_specs)
        self._jit_extend = wrap(self._extend, 3, state_specs)
        self._jit_draft_phase = wrap(self._draft_phase, 1, R)
        self._jit_target_phase = wrap(self._target_phase, 1, R)

    def _prefix_supported(self) -> bool:
        """Sharing is exact iff every layer of all three models keeps its
        serving state in the paged (position-addressed) KV pools."""
        def attention_only(model):
            kinds = list(model.pattern) * model.repeats \
                + list(model.remainder)
            return all(k in ("full", "local") for k in kinds)
        return all(attention_only(m)
                   for m in (self.draft, self.target, self.prm))

    # ------------------------------------------------------------------
    # State
    # ------------------------------------------------------------------
    def _fresh_caches(self, batch: int, *, pages: int = 0):
        kw = dict(pages=pages, page_size=self.page_size,
                  kv_dtype=self.kv_dtype) if pages else {}
        return {
            "S": self.draft.init_cache(batch, self.max_seq, **kw),
            "B": self.target.init_cache(batch, self.max_seq, **kw),
            "P": self.prm.init_cache(batch, self.max_seq, **kw),
        }

    def fresh_state(self, batch: int):
        """An all-free slot-pool state: every row is done/inert until a
        prompt is admitted into it (scheduler API)."""
        state = {
            "pending": jnp.full((batch,), PAD, jnp.int32),
            "pos": jnp.zeros((batch,), jnp.int32),
            "done": jnp.ones((batch,), bool),
        }
        self._known_pos = np.zeros((batch,), np.int64)
        self._known_done = np.ones((batch,), bool)
        self._inflight_steps = 0
        if not self.paged:
            state["caches"] = self._fresh_caches(batch)
            return self._place_state(state)
        # paged layout: `num_pages` allocatable pages + a static scratch
        # region for copy-on-write branching + one trash page that absorbs
        # the benign garbage-at-pos writes of done/never-admitted rows.
        self.num_pages = self._num_pages or batch * self.nblk
        n_scratch = batch * self.nmax * self.span
        total = self.num_pages + n_scratch + 1
        index = RadixIndex(self.page_size) if self.prefix_cache else None
        # bytes-weighted LRU: the pool knows what one page of this
        # engine's kv_dtype actually costs (payload + quant scales), so
        # cached quantized pages are evicted at half the priority of
        # full-precision ones of equal staleness
        mem = self.cache_memory_report(batch)
        self.pager = PagePool(self.num_pages, self.page_size, index=index,
                              kv_dtype=self.kv_dtype,
                              page_bytes=mem["bytes_per_page"]
                              + mem["scale_bytes_per_page"])
        self._trash = total - 1
        self._released = set()
        scratch = (self.num_pages
                   + np.arange(n_scratch, dtype=np.int32)
                   ).reshape(batch, self.nmax, self.span)
        state["caches"] = self._fresh_caches(batch, pages=total)
        # block table: one extra (trash) column absorbs clamped writes at
        # pos == max_seq; unassigned entries also point at the trash page
        state["pt"] = jnp.full((batch, self.nblk + 1), total - 1, jnp.int32)
        state["scratch"] = jnp.asarray(scratch)
        # the page allocator is engine-held host state, so a paged engine
        # backs ONE live state at a time: creating a new state invalidates
        # every older one (stepping a stale state raises, see _check_gen)
        self._gen += 1
        state["gen"] = jnp.asarray(self._gen, jnp.int32)
        return self._place_state(state)

    def _place_state(self, state):
        """Mesh mode: place a fresh state on the replica's submesh (the
        target's KV leaves sharded over the kv-head axis, everything
        else replicated) and build the shard_map'd phase jits against
        its structure.  Identity on single-device engines."""
        if self.mesh is None:
            return state
        specs = serve_state_pspecs(state, self.mesh,
                                   shard_attn=self._tp_plan["attn"])
        state = jax.device_put(state, as_shardings(specs, self.mesh))
        self._build_mesh_jits(state)
        return state

    def _check_gen(self, state):
        if int(state["gen"]) != self._gen:
            raise RuntimeError(
                "stale paged state: fresh_state()/init_state() was called "
                "on this engine after the state was created, resetting the "
                "page allocator.  A paged engine backs one live state at a "
                "time; build a separate engine for concurrent states.")

    @staticmethod
    def _with_gen(new_state, state):
        """Re-attach the *concrete* generation stamp to a jitted output.

        The jitted phases thread ``gen`` through as a device array, which
        would turn ``_check_gen``'s ``int()`` into a blocking sync on the
        in-flight step.  The stamp never changes within a live state, so
        the host keeps the original concrete array attached instead.
        """
        if "gen" in state:
            new_state = dict(new_state)
            new_state["gen"] = state["gen"]
        return new_state

    def init_state(self, prompts: np.ndarray):
        """prompts: (B, Lp) PAD-padded token array.

        All-PAD rows (padding a partial batch up to capacity) start done,
        so they never decode or hold up ``run``'s all-done early exit.
        """
        B = prompts.shape[0]
        prompts = np.asarray(prompts)
        state = self.fresh_state(B)
        state["pending"] = jnp.asarray(prompts[:, 0], jnp.int32)
        done = (prompts == PAD).all(axis=1)
        state["done"] = jnp.asarray(done)
        lengths = (prompts != PAD).sum(axis=1)
        self._known_done = done.copy()
        if self.paged:
            for b in range(B):
                if lengths[b]:
                    self.pager.claim(b, self.blocks_needed(
                        int(lengths[b]), self.gcfg.max_steps))
            state = self._assign_pages(state,
                                       np.maximum(lengths - 1, 0))
        if prompts.shape[1] > 1:
            state = self._with_gen(
                self._jit_commit(state, jnp.asarray(prompts[:, 1:],
                                                    jnp.int32)), state)
        self._known_pos = np.maximum(lengths - 1, 0).astype(np.int64)
        return state

    # ------------------------------------------------------------------
    # Page accounting (host side; no-ops for the dense engine)
    # ------------------------------------------------------------------
    def positions_needed(self, prompt_len: int, budget: int) -> int:
        """Worst-case cache positions a request can touch: committed
        prompt + ``budget`` full reasoning steps.  The single source of
        the cost model — scheduler admission (max_seq check) and page
        reservation both derive from it."""
        return prompt_len - 1 + budget * self.gcfg.max_step_tokens

    def blocks_needed(self, prompt_len: int, budget: int) -> int:
        """Worst-case pages a request can touch (admission reservation)."""
        # +1 position: the trailing garbage-at-pos write of the last commit
        need = self.positions_needed(prompt_len, budget) + 1
        return min(self.nblk, pages_for(need, self.page_size))

    def match_prefix(self, prompt) -> Tuple[List[int], int]:
        """Radix lookup: the longest cached page-aligned prefix of
        ``prompt`` whose KV pages can be spliced into a new slot's block
        table (one splice covers draft/target/PRM — the unified page-id
        space keeps the three models position-aligned).

        At most the first ``len(prompt) - 1`` tokens are matchable: the
        engine invariant leaves the last prompt token *pending* (its KV row
        is written by the first decode step), so the page holding it is
        never full at admission.  Returns ``([], 0)`` when prefix caching
        is off or unsupported for this stack.
        """
        if not self.paged or self.pager is None or not self.prefix_cache:
            return [], 0
        prompt = np.asarray(prompt).reshape(-1)
        lim = (prompt.size - 1) // self.page_size * self.page_size
        return self.pager.match(prompt[:max(lim, 0)])

    def admit_ok(self, prompt_len: int, budget: int,
                 shared: Sequence[int] = ()) -> bool:
        """Can a request be admitted now?  Paged engines gate on free
        (unclaimed) pages — counting matched ``shared`` pages as already
        covered and LRU-evictable cached pages as reclaimable — so False
        means true back-pressure: defer the request."""
        if not self.paged or self.pager is None:
            return True
        tail = self.blocks_needed(prompt_len, budget) - len(shared)
        return self.pager.can_claim(tail, shared)

    def claim_slot(self, slot: int, prompt_len: int, budget: int,
                   shared: Sequence[int] = ()) -> None:
        """Reserve the request's worst-case *tail* pages, splicing the
        matched ``shared`` pages in as blocks 0..len(shared)-1 (they are
        pinned before any eviction the claim itself triggers)."""
        if self.paged:
            tail = self.blocks_needed(prompt_len, budget) - len(shared)
            self.pager.claim(slot, tail, shared=shared)

    def release_slot(self, slot: int) -> int:
        """Return a finished request's pages to the pool (no zeroing).

        The slot's block-table row is lazily re-pointed at the trash page
        before the next jitted phase, so the freed slot's benign
        garbage-at-``pos`` writes can never land in a reassigned page.
        """
        if self.paged and slot in self.pager.assigned:
            self._released.add(slot)
            return self.pager.release(slot)
        return 0

    def _flush_released(self, state):
        """Point released slots' table rows at the trash page."""
        if not self._released:
            return state
        rows = np.asarray(sorted(self._released))
        self._released = set()
        state = dict(state)
        state["pt"] = state["pt"].at[rows].set(self._trash)
        return state

    def cache_memory_report(self, batch: int) -> dict:
        """HBM accounting: dense per-slot caches vs the paged pool, and —
        the headline numbers — per-draft-step candidate-branch scratch
        (dense ``repeat_cache`` materializes n full cache copies; paged
        branching allocates ``n * span`` copy-on-write pages per slot) and
        pool *capacity* (pages / tokens / bytes at the engine's
        ``kv_dtype``: page bytes are computed from the actual pool leaf
        dtype, per-page scale tensors accounted separately, so two engines
        differing only in ``kv_dtype`` report the exact storage ratio)."""
        from repro.models.attention import _cache_len
        from repro.models.common import adtype
        g = self.gcfg

        def attn_layers(model):
            kinds = list(model.pattern) * model.repeats \
                + list(model.remainder)
            return [k for k in kinds if k not in ("rwkv", "recurrent")]

        def row_bytes(model, dtype=None):
            """Bytes per pool cache position (k+v over attention layers),
            at the *actual* page storage dtype unless overridden."""
            cfg = model.cfg
            dt = dtype or quant.pool_dtype(self.kv_dtype, adtype(cfg))
            item = jnp.dtype(dt).itemsize
            return sum(2 * cfg.num_kv_heads * cfg.head_dim * item
                       for _ in attn_layers(model))

        def scale_bytes(model):
            """Per-page bytes of the (P, KV) float32 k/v scale tensors."""
            if not quant.is_quantized(self.kv_dtype):
                return 0
            return sum(2 * model.cfg.num_kv_heads * 4
                       for _ in attn_layers(model))

        def dense_bytes(model):
            cfg = model.cfg
            item = jnp.dtype(adtype(cfg)).itemsize
            return batch * sum(
                2 * cfg.num_kv_heads * cfg.head_dim * item
                * _cache_len(cfg, k, self.max_seq)
                for k in attn_layers(model))

        n = g.n
        branched = [self.draft, self.prm]
        if self.mode in ("gsi", "gsi_norej") and not self.shared_scoring:
            branched.append(self.target)
        dense_branch = n * sum(dense_bytes(m) for m in branched)
        models = (self.draft, self.target, self.prm)
        page_b = sum(row_bytes(m) for m in models) * self.page_size
        scale_b = sum(scale_bytes(m) for m in models)
        fp_page_b = sum(row_bytes(m, adtype(m.cfg))
                        for m in models) * self.page_size
        num_pages = self.num_pages or batch * self.nblk
        n_scratch = batch * self.nmax * self.span
        total_pages = num_pages + n_scratch + 1
        rep = {
            "kv_dtype": self.kv_dtype or "fp",
            "page_size": self.page_size,
            "num_pages": num_pages,
            "scratch_pages": n_scratch,
            "bytes_per_page": page_b,
            "scale_bytes_per_page": scale_b,
            "fp_bytes_per_page": fp_page_b,
            # pool capacity at this kv_dtype: allocatable pages / tokens /
            # the HBM they cost (page payload + per-page scales)
            "capacity_pages": num_pages,
            "capacity_tokens": num_pages * self.page_size,
            "capacity_bytes": num_pages * (page_b + scale_b),
            "dense_committed_bytes": sum(dense_bytes(m) for m in models),
            "dense_branch_bytes": dense_branch,
            "paged_pool_bytes": total_pages * (page_b + scale_b),
            "paged_branch_bytes": n_scratch * (page_b + scale_b),
        }
        rep["branch_reduction"] = (
            rep["dense_branch_bytes"] / max(1, rep["paged_branch_bytes"]))
        # per-device split under the serving mesh: the target's KV pages
        # shard tp-ways along the kv-head axis; draft/PRM pages (and the
        # target's when attention can't shard) replicate on every device,
        # so each device's effective tokens-worth of HBM is the capacity
        # scaled by its byte share.
        shard = self.tp if self._tp_plan["attn"] else 1
        tgt_page = row_bytes(self.target) * self.page_size \
            + scale_bytes(self.target)
        per_dev_page = (page_b + scale_b) - tgt_page + tgt_page // shard
        rep["devices"] = 1 if self.mesh is None else \
            int(np.prod(self.mesh.devices.shape))
        rep["bytes_per_device"] = num_pages * per_dev_page
        rep["capacity_tokens_per_device"] = round(
            rep["capacity_tokens"] * rep["bytes_per_device"]
            / max(1, rep["capacity_bytes"]))
        if self.pager is not None:
            # distinct pages (num_referenced) are the HBM truth: a page
            # spliced into several slots' tables occupies one page
            rep["pages_assigned"] = self.pager.num_referenced
            rep["pages_slot_view"] = self.pager.num_assigned
            rep["pages_peak"] = self.pager.peak_assigned
            rep["paged_assigned_bytes"] = self.pager.num_referenced * page_b
            rep["paged_peak_bytes"] = self.pager.peak_assigned * page_b
            rep["pages_cached"] = self.pager.num_cached
            rep["pages_evicted"] = self.pager.evicted
            rep["prefix_cached_bytes"] = self.pager.num_cached * page_b
        return rep

    def _ensure_blocks(self, state, wants: dict, splice=None):
        """Assign pages so each slot covers ``wants[slot]`` table blocks,
        then push the new (block -> page) entries into the device table.
        ``splice`` ((rows, cols, vals) lists) folds extra table updates —
        the prefix-cache splice of shared pages — into the same scatter."""
        rows, cols, vals = splice if splice is not None else ([], [], [])
        for slot, nb in wants.items():
            for blk, page in self.pager.ensure(slot, nb):
                rows.append(slot)
                cols.append(blk)
                vals.append(page)
        if rows:
            state = dict(state)
            state["pt"] = state["pt"].at[
                np.asarray(rows), np.asarray(cols)].set(
                jnp.asarray(np.asarray(vals, np.int32)))
        return state

    def _assign_pages(self, state, ahead):
        """Lazily assign pages so every live slot's table covers the blocks
        the next jitted phase may write (up to ``pos + ahead``).

        Positions come from the engine's *host-side* mirrors
        (``_known_pos``/``_known_done``, refreshed at admit and
        materialize time) rather than the device state, so a pipelined
        dispatch never blocks on the step still executing.  When steps
        are dispatched ahead of the last materialize, the caller widens
        ``ahead`` by one ``max_step_tokens`` per in-flight step; the
        per-slot want is capped at the slot's reservation, which the
        force-done budget guarantee makes an upper bound on what it can
        actually write.
        """
        state = self._flush_released(state)
        pos = self._known_pos
        done = self._known_done
        ahead = np.broadcast_to(np.asarray(ahead), pos.shape)
        wants = {}
        for slot in list(self.pager.assigned):
            if done[slot] and self.pager.blocks_assigned(slot):
                continue          # pos is frozen; blocks already cover it
            wants[slot] = min(
                self.nblk,
                self.pager.max_blocks(slot),
                pages_for(int(pos[slot]) + int(ahead[slot]) + 1,
                          self.page_size))
        return self._ensure_blocks(state, wants)

    def force_done(self, state, mask) -> dict:
        """Mark ``mask`` slots done on the device *and* in the host
        mirror (scheduler budget exhaustion — the one finish condition
        the device cannot see).  No-op when the mask is empty."""
        mask = np.asarray(mask, bool)
        if not mask.any():
            return state
        state = dict(state)
        state["done"] = state["done"] | jnp.asarray(mask)
        self._known_done = self._known_done | mask
        return state

    # ------------------------------------------------------------------
    # Jitted phases
    # ------------------------------------------------------------------
    def _commit(self, params, state, step_tokens, row_live=None):
        """Append step_tokens (B,L) to the three committed caches."""
        ps, pb, pp = params
        caches = state["caches"]
        pt = state.get("pt")
        new = {}
        _, new["S"], pos = score_and_append(
            self.draft, ps, caches["S"], state["pending"], state["pos"],
            step_tokens, row_live=row_live, pt=pt)
        _, new["B"], _ = score_and_append(
            self.target, pb, caches["B"], state["pending"], state["pos"],
            step_tokens, row_live=row_live, pt=pt)
        _, new["P"], _, _ = score_and_append(
            self.prm, pp, caches["P"], state["pending"], state["pos"],
            step_tokens, return_rewards=True, row_live=row_live, pt=pt)
        length = jnp.sum(step_tokens != PAD, axis=1)
        if row_live is not None:
            length = jnp.where(row_live, length, 0)
        pending = jnp.where(
            length > 0,
            jnp.take_along_axis(
                step_tokens, jnp.maximum(length - 1, 0)[:, None],
                axis=1)[:, 0],
            state["pending"])
        out = {"caches": new, "pending": pending, "pos": pos,
               "done": state["done"]}
        if pt is not None:
            out["pt"], out["scratch"] = pt, state["scratch"]
            out["gen"] = state["gen"]
        return out

    def _admit(self, params, state, admit_mask, tails, starts, live):
        """Prefill prompt *tails* (B,Lt; PAD-padded) into the slots where
        ``admit_mask`` is True; every other slot passes through untouched.

        ``tails`` holds each admitted prompt shifted past its prefix-cache
        match: ``tails[b] = prompt[starts[b]:]`` (``starts[b] == 0`` — the
        whole prompt — when nothing matched).  Admitted rows are zeroed
        (stale recurrent state / ring buffers from the previous occupant;
        shared paged pools are never touched), bookkeeping is reset to the
        engine invariant (cache holds prompt[:-1], pending = prompt[-1],
        the matched prefix already living in spliced pages below
        ``starts``), and the unmatched tail is teacher-forced through all
        three models via the regular commit path with ``row_live`` masking.

        ``live`` (B,) marks which admitted rows hold their *whole* prompt:
        those come up decoding (done=False).  A chunked-prefill admission
        passes ``live=False`` — the row stays device-done (inert under the
        decode masks) until :meth:`extend` commits its final chunk, so live
        neighbours keep decoding while the long prompt trickles in.
        """
        caches = reset_cache_rows(state["caches"], admit_mask)
        new = {
            "caches": caches,
            "pending": jnp.where(admit_mask, tails[:, 0],
                                 state["pending"]),
            "pos": jnp.where(admit_mask, starts, state["pos"]),
            "done": jnp.where(admit_mask, ~live, state["done"]),
        }
        if "pt" in state:
            new["pt"], new["scratch"] = state["pt"], state["scratch"]
            new["gen"] = state["gen"]
        return self._commit(params, new, tails[:, 1:], row_live=admit_mask)

    def _extend(self, params, state, mask, chunks, live):
        """Commit continuation prefill ``chunks`` (B,W; PAD-padded) into
        mid-prefill slots where ``mask`` is True (chunked prefill).

        Each masked row's chunk is the next run of its prompt tokens: the
        regular commit path teacher-forces ``pending`` + ``chunks[:, :-1]``
        and leaves the chunk's last token pending — after the final chunk
        the row satisfies the same invariant a one-shot admit establishes
        (cache holds prompt[:-1], pending == prompt[-1], pos == len-1).
        ``live`` flips rows whose final chunk this is to done=False; rows
        mid-prefill stay device-done and inert under the decode masks.
        """
        new = self._commit(params, state, chunks, row_live=mask)
        new["done"] = jnp.where(mask, ~live, state["done"])
        return new

    def _branch(self, cache, n, state):
        """n scratch branches of a committed cache: dense n-way copy, or
        paged copy-on-write aliasing.  Returns (cache, branch_pt)."""
        if not self.paged:
            return repeat_cache(cache, n), None
        scr = state["scratch"][:, :n]
        bpt = branch_pages(state["pt"], state["pos"], scr, self.page_size)
        return branch_cache(cache, n, state["pt"], state["pos"], scr,
                            self.page_size), bpt

    def _draft_phase(self, params, state, rng):
        """Sample n draft candidates; score with target + PRM."""
        g = self.gcfg
        n = g.n
        ps, pb, pp = params
        k1, k2 = jax.random.split(rng)
        pend = expand_requests(state["pending"], n)
        pos = expand_requests(state["pos"], n)
        done = expand_requests(state["done"], n)

        scratch_s, bpt = self._branch(state["caches"]["S"], n, state)
        steps = sample_steps(
            self.draft, ps, scratch_s, pend, pos, k1,
            max_tokens=g.max_step_tokens, sep_token=g.sep_token_id,
            eos_token=g.eos_token_id, temperature=g.temperature,
            top_p=g.top_p, already_done=done, pt=bpt)

        cands = fold_candidates(steps.tokens, n)             # (B,n,L)
        # PRM rewards (always needed)
        if self.shared_scoring:
            from repro.models.scoring import score_candidates
            cache_p = state["caches"]["P"]
            if self.paged:
                cache_p = paged_view(cache_p, state["pt"])
            _, rewards = score_candidates(
                self.prm, pp, cache_p, state["pending"],
                state["pos"], cands, return_rewards=True)
        else:
            scratch_p, _ = self._branch(state["caches"]["P"], n, state)
            _, _, _, rewards_flat = score_and_append(
                self.prm, pp, scratch_p, pend, pos, steps.tokens,
                return_rewards=True, pt=bpt)
            rewards = fold_candidates(rewards_flat, n)

        out = {
            "cands": cands,
            "logp_S": fold_candidates(steps.logprob, n),     # (B,n)
            "rewards": rewards,
            "rng": k2,
        }
        if self.mode in ("gsi", "gsi_norej"):
            if self.shared_scoring:
                from repro.models.scoring import score_candidates
                cache_b = state["caches"]["B"]
                if self.paged:
                    cache_b = paged_view(cache_b, state["pt"])
                out["logp_B"] = score_candidates(
                    self.target, pb, cache_b,
                    state["pending"], state["pos"], cands)
            else:
                scratch_b, _ = self._branch(state["caches"]["B"], n, state)
                logp_B, _, _ = score_and_append(
                    self.target, pb, scratch_b, pend, pos, steps.tokens,
                    pt=bpt)
                out["logp_B"] = fold_candidates(logp_B, n)
            dec = gsi_select(k2, out["rewards"], out["logp_B"],
                             out["logp_S"], beta=g.beta,
                             threshold_u=g.threshold_u)
            accept = dec.accept if (self.mode == "gsi" and g.use_rejection) \
                else jnp.ones_like(dec.accept)
            out.update(index=dec.index, accept=accept,
                       selected=dec.selected_tilted, tilted=dec.tilted)
        elif self.mode == "rsd":
            dec = rsd_select(k2, out["rewards"], beta=g.beta,
                             threshold=self.rsd_threshold)
            out.update(index=dec.index, accept=dec.accept,
                       selected=dec.selected_reward, tilted=out["rewards"])
        else:  # sbon_s: always accept the soft-BoN choice
            idx = soft_bon_select(k2, out["rewards"], g.beta)
            out.update(index=idx, accept=jnp.ones((idx.shape[0],), bool),
                       selected=take_per_request(out["rewards"], idx),
                       tilted=out["rewards"])
        out["chosen"] = take_candidates(out["cands"], out["index"])
        out["max_reward"] = jnp.max(out["rewards"], axis=-1)
        return out

    def _target_phase(self, params, state, rng):
        """S-BoN with the target model (rejection fallback / sbon_b)."""
        g = self.gcfg
        n = g.n_target or g.n
        _, pb, pp = params
        k1, k2 = jax.random.split(rng)
        pend = expand_requests(state["pending"], n)
        pos = expand_requests(state["pos"], n)
        done = expand_requests(state["done"], n)

        scratch_b, bpt = self._branch(state["caches"]["B"], n, state)
        steps = sample_steps(
            self.target, pb, scratch_b, pend, pos, k1,
            max_tokens=g.max_step_tokens, sep_token=g.sep_token_id,
            eos_token=g.eos_token_id, temperature=g.temperature,
            top_p=g.top_p, already_done=done, pt=bpt)
        scratch_p, _ = self._branch(state["caches"]["P"], n, state)
        _, _, _, rewards = score_and_append(
            self.prm, pp, scratch_p, pend, pos, steps.tokens,
            return_rewards=True, pt=bpt)
        cands = fold_candidates(steps.tokens, n)
        r = fold_candidates(rewards, n)
        idx = soft_bon_select(k2, r, g.beta)
        return {"chosen": take_candidates(cands, idx), "cands": cands,
                "rewards": r, "selected": take_per_request(r, idx)}

    # ------------------------------------------------------------------
    # Host loop
    # ------------------------------------------------------------------
    def _decode_core(self, params, state, rng, rng_target):
        """One whole engine step as a single traced computation.

        Draft phase, the rejection-fallback target phase under a
        device-side ``lax.cond`` (it runs iff any live slot rejected —
        exactly when the host-checked path used to run it, and
        ``jnp.where`` selection makes the all-accept case bit-identical
        to skipping it), commit, and the EOS / B.2 done fold.  Returns
        ``(new_state, StepTicket)`` — everything a pipelined caller needs
        without a host round-trip.
        """
        g = self.gcfg
        if self.mode == "sbon_b":
            tp = self._target_phase(params, state, rng)
            chosen = tp["chosen"]
            accept = jnp.ones_like(state["done"])
            max_r = jnp.max(tp["rewards"], axis=-1)
            draft_count = jnp.zeros((), jnp.int32)
            target_count = jnp.sum(tp["cands"] != PAD).astype(jnp.int32)
            rewards = tilted = ratio = None
        else:
            dp = self._draft_phase(params, state, rng)
            accept = dp["accept"]
            max_r = dp["max_reward"]
            draft_count = jnp.sum(dp["cands"] != PAD).astype(jnp.int32)
            rewards = dp["rewards"]
            tilted = dp["tilted"] if "logp_B" in dp else None
            ratio = (dp["logp_B"] - dp["logp_S"]) if "logp_B" in dp \
                else None

            def fallback(_):
                tp = self._target_phase(params, state, rng_target)
                return (tp["chosen"],
                        jnp.sum(tp["cands"] != PAD).astype(jnp.int32))

            def no_fallback(_):
                return (jnp.zeros_like(dp["chosen"]),
                        jnp.zeros((), jnp.int32))

            tp_chosen, target_count = jax.lax.cond(
                jnp.all(accept), no_fallback, fallback, None)
            chosen = jnp.where(accept[:, None], dp["chosen"], tp_chosen)
        done_prev = state["done"]
        # early stop (paper B.2): all draft rewards below min threshold
        failed = max_r < g.min_step_reward
        new_state = self._commit(params, state, chosen)
        eos = jnp.any(chosen == g.eos_token_id, axis=1)
        new_done = done_prev | eos | (failed & ~done_prev)
        new_state["done"] = new_done
        ticket = StepTicket(
            chosen=chosen, done_prev=done_prev, eos=eos, failed=failed,
            accept=accept, done=new_done, pos=new_state["pos"],
            draft_tokens=draft_count, target_tokens=target_count,
            rewards=rewards, tilted=tilted, logp_ratio=ratio)
        return new_state, ticket

    def dispatch_decode(self, state, rng, rng_target=None):
        """Enqueue one engine step; returns ``(state, StepTicket)``.

        Non-blocking: page assignment reads the host-side position
        mirrors, the jitted step is dispatched asynchronously, and no
        device value is fetched — the host is free to overlap admission
        and harvest work with the step's device execution.  Pair with
        :meth:`materialize`; ``step_decode`` is the synchronous
        composition of the two.
        """
        g = self.gcfg
        if rng_target is None:
            rng, rng_target = jax.random.split(rng)
        if self.paged:
            self._check_gen(state)
            # page in the blocks every in-flight step may write: one
            # max_step_tokens of look-ahead per dispatched-unharvested step
            ahead = (self._inflight_steps + 1) * g.max_step_tokens
            state = self._assign_pages(state, ahead)
        new_state, ticket = self._jit_step(state, rng, rng_target)
        new_state = self._with_gen(new_state, state)
        self._inflight_steps += 1
        return new_state, ticket

    def materialize(self, ticket: StepTicket) -> StepResult:
        """Transfer a dispatched step's whole outcome to the host.

        One batched ``device_get`` over every ticket array (blocking only
        until the step's device execution completes), refreshing the
        host-side ``pos``/``done`` mirrors the next dispatch assigns
        pages from.  Stats folding is split out (:meth:`fold_step_stats`)
        so a pipelined scheduler can defer it off the dispatch path.
        """
        host = jax.device_get(
            {n: v for n, v in zip(StepTicket._fields, ticket)
             if v is not None})
        kw = {n: host.get(n) for n in StepTicket._fields}
        kw["draft_tokens"] = int(kw["draft_tokens"])
        kw["target_tokens"] = int(kw["target_tokens"])
        self._known_pos = np.array(kw["pos"], np.int64)
        self._known_done = np.array(kw["done"], bool)
        self._inflight_steps = max(0, self._inflight_steps - 1)
        return StepResult(**kw)

    def fold_step_stats(self, res: StepResult, stats: EngineStats,
                        collect_stats: bool = False) -> None:
        """Fold one materialized step into ``stats``.

        Exactly the accounting the synchronous ``step_decode`` always
        did, factored out so the pipelined scheduler can run it while the
        next step executes on device.
        """
        if self.mode == "sbon_b":
            stats.bump(steps=1, target_tokens=res.target_tokens)
            return
        live = ~res.done_prev
        stats.bump(steps=1, draft_tokens=res.draft_tokens,
                   target_tokens=res.target_tokens,
                   decisions=int(live.sum()),
                   accepted=int((res.accept & live).sum()))
        if collect_stats:
            stats.record_trace("raw_rewards", res.rewards)
            if res.logp_ratio is not None:
                stats.record_trace("logp_ratio", res.logp_ratio)
                stats.record_trace("tilted_rewards", res.tilted)

    def step_decode(self, state, rng, rng_target=None, *,
                    stats: Optional[EngineStats] = None,
                    collect_stats: bool = False):
        """One engine step over the whole (fixed-size) batch.

        Runs the mode's phase(s) on every live slot (done slots are masked
        and stay inert), commits the chosen step to the three caches, and
        folds EOS / B.2 early-stop into ``state["done"]``.  Returns
        ``(state, StepResult)``; the caller (``run`` or the
        continuous-batching scheduler) owns response assembly.  This is
        ``dispatch_decode`` + ``materialize`` back-to-back — the
        synchronous and pipelined schedulers run the same compiled step.
        """
        state, ticket = self.dispatch_decode(state, rng, rng_target)
        res = self.materialize(ticket)
        if stats is not None:
            self.fold_step_stats(res, stats, collect_stats)
        return state, res

    def admit(self, state, admit_mask: np.ndarray, prompts: np.ndarray,
              starts=None, live=None):
        """Scheduler API: prefill ``prompts`` (B,Lp) into masked slots.

        ``starts`` (B,) gives each admitted slot's prefix-cache match
        length (a multiple of ``page_size``; 0 = no match).  Matched blocks
        are spliced into the slot's table from the pages its claim was
        seeded with, only the tail ``prompt[start:]`` is prefilled, and the
        prompt's full committed pages are published to the radix index
        *after* the prefill commit is ordered on the device stream — a
        request admitted on the same step can never match pages whose
        content is still being written.

        ``live`` (B,) bool (default all-True) marks rows admitted with
        their whole prompt.  Chunked prefill admits a *truncated* prompt
        with ``live=False``: the row stays device-done (inert) and the
        scheduler streams the rest in with :meth:`extend`.  The caller's
        page claim must cover the full prompt either way (``claim_slot``
        with the real prompt length).
        """
        admit_mask = np.asarray(admit_mask, bool)
        prompts = np.asarray(prompts, np.int32)
        B = prompts.shape[0]
        live_np = np.ones((B,), bool) if live is None \
            else np.asarray(live, bool)
        starts_np = np.zeros((B,), np.int32) if starts is None \
            else np.asarray(starts, np.int32).copy()
        publish = []
        if self.paged:
            self._check_gen(state)
            state = self._flush_released(state)
            lengths = (prompts != PAD).sum(axis=1)
            wants = {}
            rows, cols, vals = [], [], []
            for slot in np.nonzero(admit_mask)[0]:
                slot = int(slot)
                if slot not in self.pager.assigned:
                    # direct engine use (no scheduler claim): worst case
                    starts_np[slot] = 0
                    self.claim_slot(slot, int(lengths[slot]),
                                    self.gcfg.max_steps)
                nshared = int(starts_np[slot]) // self.page_size
                if nshared:
                    # splice matched pages in as table blocks 0..nshared-1
                    for blk, page in enumerate(
                            self.pager.assigned[slot][:nshared]):
                        rows.append(slot)
                        cols.append(blk)
                        vals.append(page)
                # tail prefill writes positions start .. Lp-1
                wants[slot] = min(self.nblk,
                                  pages_for(max(int(lengths[slot]), 1),
                                            self.page_size))
                full = max(int(lengths[slot]) - 1, 0) // self.page_size
                if self.prefix_cache and full:
                    publish.append(
                        (prompts[slot, :full * self.page_size], slot, full))
            state = self._ensure_blocks(state, wants,
                                        splice=(rows, cols, vals))
        elif starts_np.any():
            raise ValueError("prefix-cache starts require a paged engine")
        tails = pack_tails(prompts, starts_np)
        out = self._with_gen(
            self._jit_admit(state, jnp.asarray(admit_mask),
                            jnp.asarray(tails), jnp.asarray(starts_np),
                            jnp.asarray(live_np)),
            state)
        for tokens, slot, full in publish:
            self.pager.publish(tokens, self.pager.assigned[slot][:full])
        # refresh the host mirrors: an admitted slot ends the prefill at
        # pos == len(prompt) - 1 with pending == prompt[-1]; it is live
        # unless this was a partial (chunked) admission
        lengths = (prompts != PAD).sum(axis=1)
        admitted = np.nonzero(admit_mask)[0]
        self._known_pos[admitted] = np.maximum(lengths[admitted] - 1, 0)
        self._known_done[admitted] = ~live_np[admitted]
        return out

    def extend(self, state, mask: np.ndarray, chunks: np.ndarray,
               live: np.ndarray):
        """Scheduler API: commit continuation prefill chunks (chunked
        prefill) into mid-prefill slots.

        ``chunks`` (B,W; PAD-padded) holds each masked slot's next run of
        prompt tokens; ``live`` marks the rows whose final chunk this is
        (they come up decoding).  Pages for the chunk's positions are
        drawn lazily from the slot's admission claim, and the host
        ``pos``/``done`` mirrors advance so a pipelined dispatch keeps
        assigning pages without touching device state.  Publication of
        the prompt's full pages stays the *scheduler's* job (via
        :meth:`publish_prefix` after the final chunk): mid-prefill pages
        become matchable only once their content commit is ordered.
        """
        mask = np.asarray(mask, bool)
        chunks = np.asarray(chunks, np.int32)
        live_np = np.asarray(live, bool)
        lengths = (chunks != PAD).sum(axis=1)
        if self.paged:
            self._check_gen(state)
            state = self._flush_released(state)
            wants = {}
            for slot in np.nonzero(mask)[0]:
                slot = int(slot)
                # the chunk commits positions pos .. pos+len-1 plus the
                # benign garbage write at the new pos
                need = int(self._known_pos[slot]) + int(lengths[slot]) + 1
                wants[slot] = min(self.nblk,
                                  self.pager.max_blocks(slot),
                                  pages_for(need, self.page_size))
            state = self._ensure_blocks(state, wants)
        out = self._with_gen(
            self._jit_extend(state, jnp.asarray(mask),
                             jnp.asarray(chunks), jnp.asarray(live_np)),
            state)
        sel = np.nonzero(mask)[0]
        self._known_pos[sel] = self._known_pos[sel] + lengths[sel]
        self._known_done[sel] = ~live_np[sel]
        return out

    def publish_prefix(self, slot: int, tokens) -> int:
        """Publish ``slot``'s full committed pages of ``tokens`` to the
        radix index; returns the pages newly retained.

        ``tokens`` is the slot's committed context (prompt, or prompt +
        generated steps at preemption); per the engine invariant its last
        token is pending, so exactly ``(len - 1) // page_size`` pages are
        full and content-complete.  No-op on dense engines or with the
        prefix cache off.
        """
        if not self.prefix_cache or self.pager is None \
                or slot not in self.pager.assigned:
            return 0
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        full = min(max(tokens.size - 1, 0) // self.page_size,
                   len(self.pager.assigned[slot]))
        if not full:
            return 0
        return self.pager.publish(tokens[:full * self.page_size],
                                  self.pager.assigned[slot][:full])

    def preempt_slot(self, slot: int, tokens) -> int:
        """Pause a live slot: publish its full committed pages (so a later
        re-admission splices them back via the regular prefix match) and
        release the slot's pages/claim.  Returns the pages published.

        Publication must precede release — ``publish`` requires the
        caller to hold a reference to every published page.  The caller
        owns the rest of the lifecycle: force-done the row, free the
        scheduler slot and requeue ``tokens`` as the resume prompt.
        """
        published = self.publish_prefix(slot, tokens)
        self.release_slot(slot)
        return published

    def save_cache(self, state, path=None, *, roots=None) -> dict:
        """Snapshot the hot (refcount-free cached) radix subtrees of the
        live ``state``: token chunk keys, LRU clocks and the cached
        pages' KV rows — scale rows included for quantized pools.

        Returns the host-side snapshot dict (``serving.snapshot``
        format) and, when ``path`` is given, also writes it to disk as
        a single ``.npz``.  ``roots`` restricts the snapshot to the
        given preamble-group chunks (cache migration pushes one group);
        ``None`` snapshots everything cached.  No-op (empty snapshot)
        on dense engines or with the prefix cache off.
        """
        from repro.serving.snapshot import save_snapshot, snapshot_state
        snap = snapshot_state(self, state, roots=roots)
        if path is not None:
            save_snapshot(snap, path)
        return snap

    def load_cache(self, state, snapshot):
        """Splice a snapshot (dict or ``.npz`` path) into the live
        ``state``'s prefix cache; returns the new state.

        Page ids are remapped through the page pool's free list —
        restoring never overwrites pages currently referenced by live
        slots — and when the pool has fewer free pages than the
        snapshot has records only the coldest subtrees are dropped.
        The conservation ledger and ``scale_slots`` lockstep hold after
        every restore; restoring an empty snapshot is the identity.
        """
        from repro.serving.snapshot import load_snapshot, restore_state
        if isinstance(snapshot, (str, bytes)) or hasattr(snapshot,
                                                         "__fspath__"):
            snapshot = load_snapshot(snapshot)
        return restore_state(self, state, snapshot)

    def run(self, prompts: np.ndarray, rng, *,
            collect_stats: bool = True):
        """Fixed-batch run-to-completion: generate until EOS/max_steps.

        Returns (responses, stats); responses is a list of B lists of
        step-token arrays.  Kept as the simple batch API — the
        continuous-batching path lives in ``repro.serving.scheduler``.
        """
        g = self.gcfg
        B = prompts.shape[0]
        state = self.init_state(prompts)
        stats = EngineStats()
        responses = [[] for _ in range(B)]

        res = None
        for it in range(g.max_steps):
            rng, k1, k2 = jax.random.split(rng, 3)
            state, res = self.step_decode(state, k1, k2, stats=stats,
                                          collect_stats=collect_stats)
            for b in range(B):
                if not res.done_prev[b]:
                    toks = res.chosen[b][res.chosen[b] != PAD]
                    responses[b].append(toks)
            if res.done.all():
                break
        stats.requests_finished = 0 if res is None else int(res.done.sum())
        return responses, stats
