"""Int8 weight quantization for the draft model (quantize at load).

In GSI the draft model's decode is the per-token hot path, so its matmul
weights are the raw speed lever: stored int8 with per-channel scales they
cost half the bytes of bf16 (a quarter of fp32) and, on hardware with
int8 matmul units, the dequant folds into the matmul epilogue.

This module implements the *numerics* of that scheme as fake
quantization: weights are quantized to int8 per-channel and immediately
dequantized back to the parameter dtype at engine load, so every
downstream matmul sees exactly the values an int8 kernel would compute
with, while the CPU-reference model code stays unchanged.  Accuracy is
therefore honest — speculative acceptance-rate and reward drift measured
on the fake-quant path equal the real int8 deployment's — and asserted
statistically (bounded drift, not token identity) by tests/test_quant.py
and ``benchmarks/throughput.py --check``.

Channel choice rides the :class:`~repro.models.common.ParamSpec` axis
names, so it works across every draft family (attention, recurrent,
RWKV) without per-module special cases:

* the trailing axis is the output-channel axis: scales keep it and
  reduce the leading (input) axes, except a ``layer`` stack axis which
  is always kept (per-layer scales);
* when the *input* side is a single named axis that is not the trailing
  one (e.g. ``wq``'s ``embed`` in ``(embed, heads, head)``), only that
  axis is reduced — finer per-(head, head_dim) channels for the QKV
  projections;
* embeddings / unembeddings, the PRM reward head, and any leaf with
  fewer than two non-layer dims (norm gains, biases, decay vectors)
  stay full precision — they are cheap and quantization-sensitive.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import quant
from repro.models.common import is_param_spec

#: Top-level parameter groups never quantized.
_SKIP_GROUPS = ("embed", "reward_head")

#: Axis names that mark a reducible *input* dimension of a weight.
_INPUT_AXES = ("embed", "mlp")


def _reduce_axes(spec) -> tuple:
    """Axes of ``spec`` to amax-reduce for per-channel scales.

    Keeps the trailing (output-channel) axis and any ``layer`` stack
    axis; prefers reducing exactly the named input axes when present,
    falling back to all other leading axes.
    """
    nd = len(spec.shape)
    keep = {nd - 1}
    keep.update(i for i, name in enumerate(spec.axes) if name == "layer")
    named = tuple(i for i, name in enumerate(spec.axes)
                  if name in _INPUT_AXES and i not in keep)
    if named:
        return named
    return tuple(i for i in range(nd) if i not in keep)


def _fake_quant_leaf(arr, spec):
    """Quantize-dequantize one weight leaf to int8 per-channel."""
    axes = _reduce_axes(spec)
    if not axes:
        return arr                      # nothing to reduce over: keep fp
    f = arr.astype(jnp.float32)
    amax = jnp.max(jnp.abs(f), axis=axes, keepdims=True)
    sc = jnp.maximum(amax, quant.EPS) / quant.QMAX["int8"]
    codes = quant.quantize_codes(f / sc, jnp.int8)
    return (codes.astype(jnp.float32) * sc).astype(arr.dtype)


def quantize_draft_params(cfg, params):
    """Fake-quantize a draft model's matmul weights to int8 at load.

    ``cfg`` is the draft's ModelConfig (used to rebuild the ParamSpec
    tree whose axis names pick the channel layout); ``params`` the
    materialized parameter tree.  Returns a new tree of the same
    structure/dtypes where every quantizable weight has been rounded
    through int8; embeddings, heads and sub-matrix leaves pass through
    untouched.
    """
    from repro.models import build_model
    specs = build_model(cfg).param_specs()

    def walk(spec_node, param_node, skip):
        if is_param_spec(spec_node):
            if skip or len(spec_node.shape) < 2 or \
                    sum(1 for a in spec_node.axes if a != "layer") < 2:
                return param_node
            return _fake_quant_leaf(param_node, spec_node)
        return {k: walk(spec_node[k], param_node[k],
                        skip or k in _SKIP_GROUPS)
                for k in param_node}

    return walk(specs, params, False)


def quantized_fraction(cfg, params) -> float:
    """Fraction of parameter *elements* the int8 scheme touches.

    Reporting helper for benchmarks: with the same rules as
    :func:`quantize_draft_params`, what share of the draft's parameters
    would actually be stored int8 (the bytes-saved headline).
    """
    from repro.models import build_model
    specs = build_model(cfg).param_specs()
    total, touched = 0, 0

    def walk(spec_node, param_node, skip):
        nonlocal total, touched
        if is_param_spec(spec_node):
            n = int(jnp.size(param_node))
            total += n
            if not (skip or len(spec_node.shape) < 2 or
                    sum(1 for a in spec_node.axes if a != "layer") < 2):
                touched += n
            return
        for k in param_node:
            walk(spec_node[k], param_node[k], skip or k in _SKIP_GROUPS)

    walk(specs, params, False)
    return touched / max(1, total)
