"""Continuous-batching request scheduler for the GSI serving engine.

The engine decodes a *fixed-capacity* batch (one jit compilation, stable
shapes); the scheduler keeps that batch full.  Requests wait in an arrival
queue, admission control maps them onto free slots of the
:class:`~repro.serving.slots.SlotPool` (prompt prefill into the vacated
row via the engine's masked ``admit`` commit), and every engine step the
scheduler harvests finished slots — EOS, per-request step budget, or the
paper's B.2 early-stop — frees them, and admits the next queued prompts on
the following step.  This is the serving-layer analogue of the capacity
reclamation in Speculative Rejection (Sun et al., 2024) / RSD (Liao et
al., 2025): a request that finishes at step 3 stops paying for its three
KV-cache rows immediately instead of idling until the slowest request in
its gang completes.

With a paged engine, admission also consults the radix prefix cache
(serving/pages.py + serving/radix.py): the longest cached page-aligned
prefix of each prompt is spliced into the new slot's block table, only
the unmatched tail is prefilled and reserved, and under pool pressure
LRU unreferenced cached pages are evicted before a request is ever
deferred.  ``prefix_stats()`` reports hit/evict/reuse counters.

``continuous=False`` degrades to gang scheduling (admit only into an empty
pool, run the batch to completion) — the fixed-batch ``run()`` discipline,
timed against the continuous mode in ``benchmarks/throughput.py``.

``sync=False`` turns the lock-step loop into a two-stage pipeline: the
scheduler keeps one dispatched :class:`~repro.serving.gsi_engine.StepTicket`
in flight and runs step k+1's host work — the previous step's harvest
(token slicing, response assembly, stats folding) and admission — while
step k executes on the device.  Slot release stays *deferred one step*:
a slot whose request finishes at step k is released only after step k's
ticket has been materialized to host memory, so a slot is never
reacquired before its final tokens are harvested, and admission then sees
exactly the free-slot/free-page view the synchronous scheduler would —
which is what makes async == sync tokens bit-identical (same engine
steps, same slots, same rng keys) at any temperature.

Three SLO-facing mechanisms ride on top (``docs/SERVING.md`` "Traffic
shaping & SLOs"):

* **chunked prefill** (``chunk_tokens > 0``) — prompt prefill is metered
  to at most ``chunk_tokens`` tokens per engine step, shared between new
  admissions and mid-prefill continuations.  A long prompt is admitted
  truncated (its slot stays device-done, inert under the decode masks)
  and grows by one chunk per step via the engine's ``extend`` commit, so
  in-flight requests keep decoding instead of stalling behind one giant
  prefill.  At temperature 0 the committed context — hence the decoded
  tokens — is identical chunked or not.
* **priority preemption** — requests carry ``priority`` (larger = more
  urgent) and optional ``deadline_s``.  When a higher-priority arrival
  cannot be admitted, the lowest-priority live slot is *paused*: its
  full committed pages are published into the radix cache, the slot and
  pages are released, and the request re-queues with prompt + generated
  tokens as its resume context (spliced straight back from the cache on
  re-admission).  Preempt == pause, never drop; page conservation
  ``free + referenced + cached == num_pages`` holds across every
  preempt/resume.
* **token streaming** — ``submit(..., stream=cb)`` delivers a
  :class:`StreamEvent` per harvested step (and a final event) in
  materialize order, giving per-request TTFT and inter-token latency;
  :class:`TokenStream` adapts the callback to a blocking iterator.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.serving.gsi_engine import (EngineStats, GSIServingEngine,
                                      StepResult, StepTicket)
from repro.serving.slots import PAD, SlotPool, pack_prompts


@dataclass
class Request:
    """A queued prompt awaiting admission (scheduler-internal record)."""

    id: str
    prompt: np.ndarray            # 1-D int32 token array (no padding)
    max_steps: int                # per-request reasoning-step budget
    arrival_time: float = 0.0     # seconds after scheduler start
    submitted_at: float = 0.0     # wall clock (perf_counter) at submit
    priority: int = 0             # larger = more urgent (0 = default class)
    deadline_s: Optional[float] = None   # SLO: finish within s of arrival


@dataclass
class Response:
    """One finished request: its step tokens, finish reason and timing."""

    request_id: str
    steps: List[np.ndarray] = field(default_factory=list)
    finish_reason: str = ""       # "eos" | "low_reward" | "max_steps"
    engine_steps: int = 0         # decode steps this request consumed
    admitted_at: float = 0.0      # seconds since scheduler start
    finished_at: float = 0.0
    arrival_time: float = 0.0
    priority: int = 0
    deadline_s: Optional[float] = None
    first_token_at: Optional[float] = None  # scheduler clock, first token
    preemptions: int = 0          # times this request was paused/resumed

    @property
    def tokens(self) -> np.ndarray:
        """All committed step tokens concatenated (PAD stripped)."""
        if not self.steps:
            return np.zeros((0,), np.int32)
        return np.concatenate([np.asarray(s, np.int32) for s in self.steps])

    @property
    def num_tokens(self) -> int:
        """Total committed tokens across the response's steps."""
        return int(self.tokens.size)

    @property
    def latency(self) -> float:
        """Queueing + decode latency, seconds since the request arrived."""
        return self.finished_at - self.arrival_time

    @property
    def ttft(self) -> float:
        """Time to first committed token since arrival (NaN if none)."""
        if self.first_token_at is None:
            return float("nan")
        return self.first_token_at - self.arrival_time

    @property
    def tpot(self) -> float:
        """Mean inter-token latency after the first token (time per
        output token; NaN with fewer than two tokens)."""
        n = self.num_tokens
        if self.first_token_at is None or n < 2:
            return float("nan")
        return (self.finished_at - self.first_token_at) / (n - 1)

    @property
    def deadline_missed(self) -> bool:
        """True iff a deadline was set and the total latency blew it."""
        return self.deadline_s is not None and self.latency > self.deadline_s


@dataclass
class StreamEvent:
    """One incremental streaming update for a request.

    Per-step events carry the step's non-PAD tokens in materialize order;
    the final event (``final=True``, possibly zero tokens) carries the
    finish reason.  ``t`` is the scheduler clock (seconds since start),
    so ``t`` of the first event minus the request's arrival time is its
    observed TTFT and gaps between events are inter-token latencies.
    """

    request_id: str
    tokens: np.ndarray
    step: int                     # engine steps the request has consumed
    final: bool = False
    finish_reason: str = ""
    t: float = 0.0


class TokenStream:
    """Thread-safe stream consumer: a callback that is also an iterator.

    Pass an instance as ``submit(..., stream=...)`` and iterate it from
    any thread: iteration yields :class:`StreamEvent` objects as the
    scheduler harvests them and ends after the final event.  Useful with
    the threaded router fleet, where the callback fires on a replica
    thread while the consumer iterates on the caller's.
    """

    def __init__(self):
        """Create an empty, open stream."""
        self._events: deque = deque()
        self._cv = threading.Condition()
        self._closed = False

    def __call__(self, event: StreamEvent) -> None:
        """Producer side: enqueue one event (scheduler harvest thread)."""
        with self._cv:
            self._events.append(event)
            if event.final:
                self._closed = True
            self._cv.notify_all()

    def __iter__(self):
        """Consumer side: block for events until the final one arrives."""
        while True:
            with self._cv:
                while not self._events and not self._closed:
                    self._cv.wait()
                if not self._events:
                    return
                event = self._events.popleft()
            yield event
            if event.final:
                return


@dataclass
class _InflightStep:
    """A dispatched-but-unmaterialized engine step (async pipeline).

    ``bound`` snapshots slot -> partial :class:`Response` at dispatch
    time, so the harvest attributes the step's rows to the requests that
    actually occupied the slots — even after the slots are released and
    re-admitted to newer requests.
    """

    ticket: StepTicket
    bound: Dict[int, Response]


@dataclass
class _RetiredStep:
    """A materialized step awaiting its deferred (overlapped) harvest.

    ``res`` is host numpy (the ticket was materialized before any of its
    slots could be released), so the heavy per-slot token slicing and
    response finalization can safely run while the *next* step executes
    on device.  ``finished`` carries the finish decisions — (slot,
    response, reason, finished_at) — made at release time.
    """

    res: StepResult
    bound: Dict[int, Response]
    finished: List[Tuple[int, Response, str, float]]


@dataclass
class _Prefill:
    """A slot mid chunked-prefill: how much of the prompt is committed.

    ``committed`` counts prompt tokens the engine holds for the slot
    (including the pending one), prefix-cache match included; the next
    chunk is ``req.prompt[committed : committed + chunk]``.  The slot is
    claimed and device-done until its final chunk commits.
    """

    req: Request
    committed: int


class GSIScheduler:
    """Drives ``GSIServingEngine.step_decode`` over a slot pool.

    Parameters
    ----------
    engine:      a built :class:`GSIServingEngine` (any mode).
    capacity:    number of slots == engine batch size (jit-stable).
    continuous:  admit into freed slots mid-flight (True) or only into an
                 empty pool (False, gang/fixed-batch discipline).
    collect_stats: forward per-step reward/ratio arrays into ``stats``.
    cache_aware: admission-ordering policy — when True, arrived queued
                 requests whose prompts have a *live* radix prefix match
                 are admitted before requests that would prefill cold.
                 Admitting a hit first both skips prefill work now and
                 keeps the matched pages referenced (they cannot be
                 evicted under pool pressure while the hit is decoding).
                 Requests with equal match state keep arrival order, a
                 deferral (out of pages) still blocks the whole queue,
                 and the queue head is never bypassed more than a
                 bounded number of consecutive admissions — so even an
                 endless stream of fresher cache hits cannot starve a
                 cold request.  Off by default because it reorders
                 sampling streams (router replicas enable it).
    sync:        True (default) runs the lock-step loop: every ``step``
                 dispatches one engine step and blocks for its results.
                 False runs the two-stage pipeline: one ticket stays in
                 flight and the previous step's harvest overlaps the
                 device execution (``step`` then returns the responses
                 *finalized* this call, which lag the decode by one
                 step until the pipeline drains).  Token streams are
                 bit-identical either way.
    chunk_tokens: per-engine-step prefill token budget (0 = off: whole
                 prompts prefill in one admit).  When set, admissions and
                 mid-prefill continuations share at most ``chunk_tokens``
                 committed prompt tokens per step, interleaved with the
                 live slots' decode — a long prompt no longer stalls
                 in-flight requests.  Greedy (temperature-0) outputs are
                 identical with chunking on or off.
    """

    def __init__(self, engine: GSIServingEngine, *, capacity: int,
                 continuous: bool = True, prompt_pad_len: int = 0,
                 collect_stats: bool = False, cache_aware: bool = False,
                 sync: bool = True, chunk_tokens: int = 0):
        """Build a scheduler over ``engine`` with ``capacity`` slots."""
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        if chunk_tokens < 0:
            raise ValueError("chunk_tokens must be >= 0")
        self.engine = engine
        self.capacity = capacity
        self.continuous = continuous
        self.collect_stats = collect_stats
        self.cache_aware = cache_aware
        self.sync = sync
        self.pool = SlotPool(capacity)
        self.queue: deque = deque()
        self.state = engine.fresh_state(capacity)
        self.stats = EngineStats()
        self.responses: Dict[str, Response] = {}
        self.engine_steps = 0
        self._partial: Dict[int, Response] = {}      # slot -> in-flight
        self._steps_taken = np.zeros((capacity,), np.int64)
        self._budget = np.zeros((capacity,), np.int64)
        self._pad = int(prompt_pad_len)
        self._seq = 0
        self._t0: Optional[float] = None
        # SLO machinery: chunked prefill, priority preemption, streaming
        self._chunk = int(chunk_tokens)
        self._prefill: Dict[int, _Prefill] = {}      # slot -> mid-prefill
        self._live_req: Dict[int, Request] = {}      # slot -> its request
        # decode-time page publication bookkeeping: the slot's committed
        # context tokens (admitted prompt + every harvested step) and how
        # many full pages of it are already in the radix index
        self._ctx: Dict[int, np.ndarray] = {}        # slot -> context
        self._pub_full: Dict[int, int] = {}          # slot -> pages published
        self._paused: Dict[str, Response] = {}       # preempted, unfinished
        self._streams: Dict[str, object] = {}        # id -> stream callback
        self._ids: set = set()                       # every id ever submitted
        # cache-aware ordering may prefer hits over the queue head, but
        # never more than this many consecutive admissions (bounded
        # head-of-line starvation; FIFO order bounds everyone behind it)
        self._bypass_limit = 8
        self._head_bypassed = 0
        # async pipeline state: at most one dispatched-unmaterialized
        # ticket plus one materialized-unharvested step
        self._inflight: Optional[_InflightStep] = None
        self._retired: Optional[_RetiredStep] = None
        # idle handling: woken by submit(), waits out exact arrival gaps
        self._wake = threading.Condition()
        # host/device overlap accounting (pipeline_stats)
        self._overlap_host_s = 0.0       # host work under an in-flight step
        self._serial_host_s = 0.0        # host work with the device idle
        self._materialize_wait_s = 0.0   # blocked waiting on device results
        self._dispatch_s = 0.0           # enqueueing steps (incl. compiles)

    def fresh_state(self) -> None:
        """Reset for a new serving phase (back-to-back benchmark runs).

        Rebuilds the engine state — which, for a paged engine, also
        rebuilds the page pool and radix index — and resets *all*
        scheduler bookkeeping with it: queue, slot pool, responses and
        the stats counters ``prefix_stats()`` reads.  Without the stat
        reset a second phase on the same scheduler would report the
        previous phase's hits folded into its own (stale hit-rates).
        """
        self.state = self.engine.fresh_state(self.capacity)
        self.pool = SlotPool(self.capacity)
        self.queue.clear()
        self.stats = EngineStats()
        self.responses = {}
        self.engine_steps = 0
        self._partial = {}
        self._steps_taken[:] = 0
        self._budget[:] = 0
        self._t0 = None
        self._prefill = {}
        self._live_req = {}
        self._ctx = {}
        self._pub_full = {}
        self._paused = {}
        self._streams = {}
        self._ids = set()
        self._head_bypassed = 0
        self._inflight = None
        self._retired = None
        self._overlap_host_s = 0.0
        self._serial_host_s = 0.0
        self._materialize_wait_s = 0.0
        self._dispatch_s = 0.0

    # ------------------------------------------------------------------
    # Submission / admission control
    # ------------------------------------------------------------------
    def submit(self, prompt, *, request_id: Optional[str] = None,
               max_steps: Optional[int] = None,
               arrival_time: float = 0.0, priority: int = 0,
               deadline_s: Optional[float] = None,
               stream=None) -> str:
        """Queue a prompt; returns the request id.

        ``priority`` (larger = more urgent) orders admission across
        classes and arms preemption: a deferring higher-priority request
        pauses the lowest-priority live slot.  ``deadline_s`` is the SLO
        latency target (arrival to finish) — purely accounting, see
        ``Response.deadline_missed``.  ``stream`` is an optional callable
        (e.g. a :class:`TokenStream`) receiving one :class:`StreamEvent`
        per harvested step plus a final event.

        Request ids are unique for the scheduler's lifetime: reusing an
        id — even one whose first request already finished — raises
        (``self.responses`` is id-keyed; a silent overwrite would corrupt
        the earlier response's ledger entry).
        """
        g = self.engine.gcfg
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size < 1:
            raise ValueError("empty prompt")
        budget = int(max_steps if max_steps is not None else g.max_steps)
        if budget < 1:
            raise ValueError("max_steps must be >= 1")
        need = self.engine.positions_needed(prompt.size, budget)
        if need > self.engine.max_seq:
            raise ValueError(
                f"request needs up to {need} cache positions but engine "
                f"max_seq={self.engine.max_seq}; shorten the prompt or "
                f"lower max_steps")
        if getattr(self.engine, "paged", False):
            blocks = self.engine.blocks_needed(prompt.size, budget)
            if blocks > self.engine.num_pages:
                raise ValueError(
                    f"request needs up to {blocks} pages but the pool "
                    f"only has {self.engine.num_pages}; it could never "
                    f"be admitted")
        if request_id is None:
            while f"req-{self._seq}" in self._ids:
                self._seq += 1
            request_id = f"req-{self._seq}"
        elif request_id in self._ids:
            raise ValueError(
                f"duplicate request id {request_id!r}: ids must be unique "
                f"for the scheduler's lifetime (responses are keyed by id)")
        self._ids.add(request_id)
        if stream is not None:
            self._streams[request_id] = stream
        self._seq += 1
        self.queue.append(Request(
            id=request_id, prompt=prompt, max_steps=budget,
            arrival_time=float(arrival_time),
            submitted_at=time.perf_counter(),
            priority=int(priority), deadline_s=deadline_s))
        if len(self.queue) > 1 and \
                arrival_time < self.queue[-2].arrival_time:
            # keep the queue arrival-ordered (stable for equal arrivals) so
            # an early arrival is never head-of-line blocked behind a
            # not-yet-arrived request submitted before it
            self.queue = deque(sorted(self.queue,
                                      key=lambda r: r.arrival_time))
        with self._wake:
            self._wake.notify_all()      # run() may be idle-waiting
        return request_id

    def _now(self) -> float:
        if self._t0 is None:
            self._t0 = time.perf_counter()
        return time.perf_counter() - self._t0

    def _ready(self, now: float) -> bool:
        return bool(self.queue) and self.queue[0].arrival_time <= now

    def _pick_ready(self, now: float):
        """Pick the next request to admit.

        Returns ``(queue_index, shared_pages, hit_tokens)`` — the match
        is computed here once and reused by the admission path, so each
        candidate costs exactly one host-side trie walk.

        Selection is two-level.  First the highest *priority* among
        arrived requests wins outright — priority deliberately overrides
        both FIFO order and the bypass bound (that is what priority
        classes mean; the starvation guarantee below holds *within* a
        class).  Within the winning class: FIFO by default; with
        ``cache_aware=True`` the request with the longest live radix
        prefix match wins (a hit admitted now skips prefill and pins its
        matched pages before anything can evict them), arrival order
        breaking ties.  The class's FIFO-first request is never bypassed
        more than ``_bypass_limit`` consecutive admissions — a
        bounded-starvation guarantee that holds even against an endless
        stream of fresher cache hits.
        """
        # the arrived highest-priority class, FIFO-ordered (the queue is
        # arrival-ordered, so stop at the first future arrival)
        tier: List[int] = []
        top = None
        for i, req in enumerate(self.queue):
            if req.arrival_time > now:
                break
            if top is None or req.priority > top:
                top, tier = req.priority, [i]
            elif req.priority == top:
                tier.append(i)
        if not tier:
            tier = [0]                 # caller guarantees _ready(now)
        lead = tier[0]
        if not self.cache_aware or len(tier) == 1 \
                or self._head_bypassed >= self._bypass_limit:
            return (lead,) + self.engine.match_prefix(
                self.queue[lead].prompt)
        best = None
        for i in tier:
            shared, hit = self.engine.match_prefix(self.queue[i].prompt)
            if best is None or hit > best[2]:
                best = (i, shared, hit)
        return best

    def _admit_ready(self, now: float) -> List[str]:
        """Advance mid-prefill slots, then move arrived requests from the
        queue into free slots.

        Each admission first consults the engine's radix prefix cache: the
        longest cached page-aligned prefix of the prompt is spliced into
        the slot's block table and only the tail is prefilled.  Paged
        engines additionally gate on free pages — counting LRU-evictable
        cached pages, so admission prefers evicting cold prefix pages over
        deferring.  A request that still doesn't fit may *preempt* a
        strictly-lower-priority live slot (pause + page publication, see
        ``_preempt``); otherwise admission stops (the request stays
        queued — back-pressure, never dropped) and retries on a later
        step once finished requests have returned pages.

        With ``chunk_tokens`` set, continuations and new admissions share
        one per-step prefill token budget: a prompt whose tail exceeds
        what is left admits *truncated* (its slot inert until ``extend``
        commits the rest, one chunk per step).
        """
        budget = self._chunk if self._chunk else None
        budget = self._advance_prefill(now, budget)
        if not self.continuous and self.pool.num_live > 0:
            return []
        batch: Dict[int, Tuple[Request, np.ndarray]] = {}
        starts = np.zeros((self.capacity,), np.int32)
        live = np.ones((self.capacity,), bool)
        committed_total = 0
        while self._ready(now):
            if budget is not None and budget <= 0:
                break                  # this step's prefill budget is spent
            free = [s for s in self.pool.free_slots() if s not in batch]
            pick, shared, hit_tok = self._pick_ready(now)
            req = self.queue[pick]
            if not free or not self.engine.admit_ok(
                    req.prompt.size, req.max_steps, shared=shared):
                # a deferring higher-priority request may pause a live
                # lower-priority slot instead of waiting behind it
                if self._try_preempt(req, now):
                    continue           # re-pick: slot/pages freed, cache grew
                break                  # true back-pressure: defer, keep order
            if pick and req.priority == self.queue[0].priority:
                self._head_bypassed += 1
            elif not pick:
                self._head_bypassed = 0
            del self.queue[pick]
            slot = free[0]
            if self._inflight is not None and \
                    slot in self._inflight.bound:
                # deferred-release invariant: a slot bound by a ticket
                # still in flight has not had its final tokens
                # materialized — admission must never reacquire it
                raise RuntimeError(
                    f"slot {slot} reacquired while its step is still in "
                    f"flight (deferred-release invariant violated)")
            self.engine.claim_slot(slot, req.prompt.size, req.max_steps,
                                   shared=shared)
            tail = req.prompt.size - hit_tok
            take = tail if budget is None else min(tail, budget)
            committed = hit_tok + take
            if committed < req.prompt.size:
                # chunked admission: only prompt[:committed] prefills now
                live[slot] = False
                self._prefill[slot] = _Prefill(req=req, committed=committed)
            if budget is not None:
                budget -= take
            committed_total += take
            batch[slot] = (req, req.prompt[:committed])
            starts[slot] = hit_tok
            self.stats.bump(
                prefix_queries=1, prefix_hits=int(bool(hit_tok)),
                prefix_hit_tokens=int(hit_tok),
                prefix_pages_reused=len(shared),
                prefill_tokens=max(tail - 1, 0))
        if not batch:
            return []
        longest = max(p.size for _, p in batch.values())
        if longest > self._pad:
            # round up so prompt-length jitter doesn't retrace _jit_admit
            self._pad = -(-longest // 8) * 8
        packed = pack_prompts({s: p for s, (_, p) in batch.items()},
                              self.capacity, self._pad)
        mask = np.zeros((self.capacity,), bool)
        for slot, (req, committed_prompt) in batch.items():
            mask[slot] = True
            self.pool.claim(slot, req.id)
            self._live_req[slot] = req
            # seed the decode-publication bookkeeping: admit() publishes
            # exactly the committed prompt's full pages below
            self._ctx[slot] = np.asarray(committed_prompt, np.int32)
            self._pub_full[slot] = max(committed_prompt.size - 1, 0) \
                // self.engine.page_size
            self._steps_taken[slot] = 0
            self._budget[slot] = req.max_steps
            resp = self._paused.pop(req.id, None)
            if resp is not None:
                self.stats.bump(resumes=1)   # resumed after a preemption
            else:
                resp = Response(
                    request_id=req.id, admitted_at=now,
                    arrival_time=req.arrival_time,
                    priority=req.priority, deadline_s=req.deadline_s)
            self._partial[slot] = resp
        self.state = self.engine.admit(self.state, mask, packed, starts,
                                       live=live)
        self.stats.prefill_commit_max = max(
            self.stats.prefill_commit_max, committed_total)
        pager = getattr(self.engine, "pager", None)
        if pager is not None:
            self.stats.pages_evicted = pager.evicted
        return [req.id for req, _ in batch.values()]

    def _advance_prefill(self, now: float,
                         budget: Optional[int]) -> Optional[int]:
        """Commit the next chunk of every mid-prefill slot, spending from
        this step's prefill token ``budget``; returns what is left for
        new admissions.

        Slots advance in slot order.  A slot whose final chunk commits
        comes up live (it decodes from the next engine step — exactly
        the state a one-shot admit would have left it in) and its
        prompt's full pages are published to the radix index.
        """
        if not self._prefill:
            return budget
        mask = np.zeros((self.capacity,), bool)
        live = np.zeros((self.capacity,), bool)
        chunks: Dict[int, np.ndarray] = {}
        total = 0
        for slot in sorted(self._prefill):
            if budget is not None and budget <= 0:
                break
            pf = self._prefill[slot]
            remaining = pf.req.prompt.size - pf.committed
            take = remaining if budget is None else min(remaining, budget)
            chunks[slot] = pf.req.prompt[pf.committed:pf.committed + take]
            mask[slot] = True
            pf.committed += take
            self._ctx[slot] = pf.req.prompt[:pf.committed].astype(np.int32)
            total += take
            if budget is not None:
                budget -= take
            if pf.committed == pf.req.prompt.size:
                live[slot] = True
        if not chunks:
            return budget
        width = max(c.size for c in chunks.values())
        if self._chunk:
            # fixed width (the chunk budget, rounded up) keeps
            # _jit_extend from retracing on chunk-length jitter
            width = max(width, self._chunk)
        width = -(-width // 8) * 8
        packed = np.full((self.capacity, width), PAD, np.int32)
        for slot, c in chunks.items():
            packed[slot, :c.size] = c
        self.state = self.engine.extend(self.state, mask, packed, live)
        self.stats.prefill_commit_max = max(
            self.stats.prefill_commit_max, total)
        for slot in np.nonzero(live)[0]:
            pf = self._prefill.pop(int(slot))
            self.engine.publish_prefix(int(slot), pf.req.prompt)
            self._pub_full[int(slot)] = \
                max(pf.req.prompt.size - 1, 0) // self.engine.page_size
        return budget

    # ------------------------------------------------------------------
    # Decode-time page publication
    # ------------------------------------------------------------------
    def _publish_decode(self, slot: int, toks: np.ndarray) -> None:
        """Fold one harvested step's tokens into the slot's committed
        context and publish every newly *filled* page to the radix
        index — the decode-time extension of the admission publish.

        Runs strictly after the step's commit was ordered on the device
        stream (the step is materialized before any harvest) and before
        the slot could be released, so a published page's content is
        complete and its refcount is still held — the same ordering
        contract ``admit`` obeys.  Per the engine invariant the
        context's last token is pending, so exactly
        ``(len - 1) // page_size`` pages are full.  No-op unless the
        engine has ``decode_publish`` (and a live prefix cache).
        """
        ctx = self._ctx.get(slot)
        if ctx is None:
            return
        if toks.size:
            ctx = np.concatenate([ctx, np.asarray(toks, np.int32)])
            self._ctx[slot] = ctx
        eng = self.engine
        if not getattr(eng, "decode_publish", False):
            return
        full = max(ctx.size - 1, 0) // eng.page_size
        if full <= self._pub_full.get(slot, 0):
            return                        # no page filled this step
        published = eng.publish_prefix(slot, ctx)
        self._pub_full[slot] = full
        if published:
            self.stats.bump(decode_pages_published=published)

    def _drop_ctx(self, slot: int) -> None:
        """Forget a released/preempted slot's publication bookkeeping."""
        self._ctx.pop(slot, None)
        self._pub_full.pop(slot, None)

    # ------------------------------------------------------------------
    # Priority preemption
    # ------------------------------------------------------------------
    def _try_preempt(self, req: Request, now: float) -> bool:
        """Pause the lowest-priority live slot strictly below
        ``req.priority`` so ``req`` can admit; False if no such victim.

        Victim order: lowest priority first, then fewest decode steps
        taken (least progress to replay on engines without a prefix
        cache), then lowest slot.  Mid-prefill slots are not preemptible:
        their request has produced nothing and holds no published pages —
        pausing one would only reshuffle the prefill budget.
        """
        victim = None
        for slot in self.pool.live_slots():
            if slot in self._prefill:
                continue
            vreq = self._live_req[slot]
            if vreq.priority >= req.priority:
                continue
            key = (vreq.priority, int(self._steps_taken[slot]), slot)
            if victim is None or key < victim:
                victim = key
        if victim is None:
            return False
        self._preempt(victim[2], now)
        return True

    def _preempt(self, slot: int, now: float) -> None:
        """Pause the live request in ``slot``: publish its committed
        pages, release the slot and its pages, requeue it for resume.

        The request's committed context (prompt + every harvested step)
        becomes the resume prompt; with a radix cache its full pages were
        just published, so re-admission splices them straight back and
        re-prefills at most one page worth of tail.  The partial
        :class:`Response` parks in ``_paused`` and keeps accumulating on
        resume — preempt is a pause, never a drop, and page conservation
        (``free + referenced + cached == num_pages``) holds throughout.
        """
        req = self._live_req.pop(slot)
        resp = self._partial.pop(slot)
        # async: the victim's latest step may still await its deferred
        # harvest — fold those tokens in before building the context
        if self._retired is not None and slot in self._retired.bound:
            res = self._retired.res
            self._retired.bound.pop(slot)
            if not res.done_prev[slot]:
                toks = res.chosen[slot]
                self._emit_step(resp, toks[toks != PAD], now)
        context = np.concatenate(
            [req.prompt.astype(np.int32), resp.tokens])
        mask = np.zeros((self.capacity,), bool)
        mask[slot] = True
        self.state = self.engine.force_done(self.state, mask)
        self.engine.preempt_slot(slot, context)
        self.pool.release(slot)
        self._drop_ctx(slot)
        remaining = int(self._budget[slot] - self._steps_taken[slot])
        resp.preemptions += 1
        self.stats.bump(preemptions=1)
        self._paused[req.id] = resp
        resumed = Request(
            id=req.id, prompt=context, max_steps=remaining,
            arrival_time=req.arrival_time, submitted_at=req.submitted_at,
            priority=req.priority, deadline_s=req.deadline_s)
        self.queue.appendleft(resumed)
        if len(self.queue) > 1 and \
                self.queue[1].arrival_time < resumed.arrival_time:
            self.queue = deque(sorted(self.queue,
                                      key=lambda r: r.arrival_time))

    def preempt(self, request_id: str) -> bool:
        """Manually pause a live request (the mechanism priority
        admission uses).  Returns False when the request is not in a
        preemptible state: unknown / queued / mid-prefill / finished.

        Drains the async pipeline first so the request's final harvested
        state is known — the drain may even *finish* it (EOS already in
        flight), in which case there is nothing left to preempt.
        """
        if self._inflight is not None or self._retired is not None:
            self.flush()
        slot = self.pool.slot_of(request_id)
        if slot is None or slot in self._prefill:
            return False
        self._preempt(slot, self._now())
        return True

    def prefix_stats(self) -> Dict[str, float]:
        """Prefix-cache admission counters.

        ``queries`` and ``prefill_tokens`` count every admission on any
        engine (they are the baseline the sharing runs are compared
        against); ``hits``/``hit_tokens``/``pages_*`` stay zero for dense
        engines or when sharing is off/unsupported.
        """
        s = self.stats
        pager = getattr(self.engine, "pager", None)
        return {
            "queries": s.prefix_queries,
            "hits": s.prefix_hits,
            "hit_rate": s.prefix_hit_rate,
            "hit_tokens": s.prefix_hit_tokens,
            "pages_reused": s.prefix_pages_reused,
            "prefill_tokens": s.prefill_tokens,
            "pages_evicted": s.pages_evicted,
            "pages_published_decode": s.decode_pages_published,
            "pages_cached": 0 if pager is None else pager.num_cached,
        }

    # ------------------------------------------------------------------
    # Stepping
    # ------------------------------------------------------------------
    def step(self, rng, rng_target=None) -> List[Response]:
        """Admit ready requests, run one engine decode step, harvest and
        free finished slots.

        ``sync=True``: dispatches and materializes one step, returning
        the responses finished *this* step.  ``sync=False``: pumps the
        pipeline (harvest + admission for the in-flight step) and
        dispatches the next step without waiting for it — returned
        responses are the ones finalized this call, which lag the decode
        by one step until the pipeline drains (``flush``).
        """
        if self.sync:
            return self._step_sync(rng, rng_target)
        now = self._now()
        finished = self._pump(now)
        if self.pool.num_live:
            self._dispatch(rng, rng_target)
        else:
            finished += self.flush()
        return finished

    def _step_sync(self, rng, rng_target=None) -> List[Response]:
        """The lock-step path: one dispatched + materialized step."""
        now = self._now()
        self._admit_ready(now)
        if self.pool.num_live == 0:
            return []
        self.state, res = self.engine.step_decode(
            self.state, rng, rng_target, stats=self.stats,
            collect_stats=self.collect_stats)
        self.engine_steps += 1
        finished: List[Response] = []
        force_done = np.zeros((self.capacity,), bool)
        for slot in self.pool.live_slots():
            if res.done_prev[slot]:
                continue               # mid-prefill rows are device-inert
            resp = self._partial[slot]
            toks = res.chosen[slot]
            kept = toks[toks != PAD]
            self._emit_step(resp, kept, self._now())
            # publish the pages this step filled *before* any release
            # below could drop the slot's page references
            self._publish_decode(slot, kept)
            self._steps_taken[slot] += 1
            reason = ""
            if res.eos[slot]:
                reason = "eos"
            elif res.failed[slot]:
                reason = "low_reward"
            elif self._steps_taken[slot] >= self._budget[slot]:
                reason = "max_steps"
                force_done[slot] = True
            if reason:
                self.pool.release(slot)
                self.engine.release_slot(slot)
                del self._partial[slot]
                self._live_req.pop(slot, None)
                self._drop_ctx(slot)
                self._finalize(resp, reason, self._now())
                finished.append(resp)
        self.state = self.engine.force_done(self.state, force_done)
        return finished

    def _emit_step(self, resp: Response, toks: np.ndarray,
                   now: float) -> None:
        """Append one harvested step's tokens to ``resp`` and fire its
        stream callback (streams observe materialize order)."""
        resp.steps.append(toks)
        resp.engine_steps += 1
        if toks.size and resp.first_token_at is None:
            resp.first_token_at = now
        cb = self._streams.get(resp.request_id)
        if cb is not None and toks.size:
            cb(StreamEvent(request_id=resp.request_id, tokens=toks,
                           step=resp.engine_steps, t=now))

    def _finalize(self, resp: Response, reason: str, at: float) -> None:
        """Stamp a finished response, account its SLO and close its
        stream (one final event carrying the finish reason)."""
        resp.finish_reason = reason
        resp.finished_at = at
        self.responses[resp.request_id] = resp
        self.stats.bump(requests_finished=1)
        if resp.deadline_missed:
            self.stats.bump(deadline_misses=1)
        cb = self._streams.pop(resp.request_id, None)
        if cb is not None:
            cb(StreamEvent(request_id=resp.request_id,
                           tokens=np.zeros((0,), np.int32),
                           step=resp.engine_steps, final=True,
                           finish_reason=reason, t=at))

    # ------------------------------------------------------------------
    # Async pipeline (sync=False)
    # ------------------------------------------------------------------
    @property
    def has_pending(self) -> bool:
        """True while the pipeline holds an unharvested step."""
        return self._inflight is not None or self._retired is not None

    def _pump(self, now: float) -> List[Response]:
        """Advance the pipeline up to (not including) the next dispatch.

        Order matters for both overlap and identity:

        1. heavy-harvest the step materialized last call — token
           slicing, response finalization, stats folding — *while the
           in-flight step executes on device* (this is the overlapped
           host work the pipeline exists for);
        2. materialize the in-flight ticket (one batched ``device_get``;
           the only point the host blocks on the device);
        3. retire it: decide finish reasons, release finished slots —
           release is thereby deferred exactly one step, and the
           final tokens are already in host memory when the slot frees;
        4. admit — seeing the same freed slots and pages the
           synchronous scheduler would see before this engine step.
        """
        finished: List[Response] = []
        t0 = time.perf_counter()
        overlapped = self._inflight is not None
        if self._retired is not None:
            retired, self._retired = self._retired, None
            finished = self._harvest(retired)
        t1 = time.perf_counter()
        if overlapped:
            self._overlap_host_s += t1 - t0
        else:
            self._serial_host_s += t1 - t0
        if self._inflight is not None:
            pend, self._inflight = self._inflight, None
            res = self.engine.materialize(pend.ticket)
            t2 = time.perf_counter()
            self._materialize_wait_s += t2 - t1
            self._retire(pend, res)
            self._admit_ready(now)
            self._serial_host_s += time.perf_counter() - t2
        else:
            self._admit_ready(now)
            self._serial_host_s += time.perf_counter() - t1
        return finished

    def _retire(self, pend: _InflightStep, res: StepResult) -> None:
        """Decide finishes for a just-materialized step and free slots.

        The cheap, order-critical part of the harvest: budget counting,
        finish reasons, slot + page release and the budget force-done —
        everything admission parity with the synchronous scheduler
        depends on.  The heavy per-slot work is deferred to ``_harvest``
        via ``self._retired``.
        """
        now = self._now()
        force_done = np.zeros((self.capacity,), bool)
        finished: List[Tuple[int, Response, str, float]] = []
        for slot, resp in pend.bound.items():
            if res.done_prev[slot]:
                continue
            toks = res.chosen[slot]
            # res is already host numpy (the ticket was materialized just
            # above), so publication here has the same commit-then-publish
            # ordering as the synchronous path — and precedes the release
            self._publish_decode(slot, toks[toks != PAD])
            self._steps_taken[slot] += 1
            reason = ""
            if res.eos[slot]:
                reason = "eos"
            elif res.failed[slot]:
                reason = "low_reward"
            elif self._steps_taken[slot] >= self._budget[slot]:
                reason = "max_steps"
                force_done[slot] = True
            if reason:
                self.pool.release(slot)
                self.engine.release_slot(slot)
                del self._partial[slot]
                self._live_req.pop(slot, None)
                self._drop_ctx(slot)
                finished.append((slot, resp, reason, now))
        self.state = self.engine.force_done(self.state, force_done)
        self._retired = _RetiredStep(res=res, bound=pend.bound,
                                     finished=finished)

    def _harvest(self, retired: _RetiredStep) -> List[Response]:
        """Heavy harvest of a retired step (runs under the next step).

        Appends every bound slot's step tokens to its partial response,
        finalizes the responses whose finish reason fired, and folds the
        step into ``stats`` — all pure host numpy on data materialized
        before any of these slots could have been reused.
        """
        res = retired.res
        now = self._now()
        for slot, resp in retired.bound.items():
            if res.done_prev[slot]:
                continue
            toks = res.chosen[slot]
            self._emit_step(resp, toks[toks != PAD], now)
        done_now: List[Response] = []
        for slot, resp, reason, at in retired.finished:
            # finalize at harvest time, not retire time: the finish is
            # client-visible only once its tokens are (keeps
            # finished_at >= first_token_at, so TPOT is never negative)
            self._finalize(resp, reason, now)
            done_now.append(resp)
        self.engine.fold_step_stats(res, self.stats, self.collect_stats)
        return done_now

    def _dispatch(self, rng, rng_target=None) -> None:
        """Dispatch the next engine step and leave its ticket in flight."""
        t0 = time.perf_counter()
        self.state, ticket = self.engine.dispatch_decode(
            self.state, rng, rng_target)
        self.engine_steps += 1
        self._inflight = _InflightStep(ticket=ticket,
                                       bound=dict(self._partial))
        self._dispatch_s += time.perf_counter() - t0

    def flush(self) -> List[Response]:
        """Drain the pipeline without dispatching: materialize the
        in-flight ticket (if any) and harvest everything retired.
        Returns the responses finalized by the drain."""
        finished: List[Response] = []
        if self._inflight is not None:
            pend, self._inflight = self._inflight, None
            t0 = time.perf_counter()
            res = self.engine.materialize(pend.ticket)
            self._materialize_wait_s += time.perf_counter() - t0
            self._retire(pend, res)
        if self._retired is not None:
            retired, self._retired = self._retired, None
            t0 = time.perf_counter()
            finished = self._harvest(retired)
            self._serial_host_s += time.perf_counter() - t0
        return finished

    def pipeline_stats(self) -> Dict[str, float]:
        """Host/device overlap accounting for the async pipeline.

        ``overlap_fraction`` is the share of host *bookkeeping* time
        (harvest + admission; dispatch enqueueing and one-off jit
        compiles are reported separately as ``dispatch_s``) that ran
        while an engine step was executing on the device — 0.0 for a
        purely synchronous scheduler.  ``materialize_wait_s`` is the
        time the host spent blocked on device results.
        """
        total = self._overlap_host_s + self._serial_host_s
        return {
            "sync": self.sync,
            "overlap_host_s": self._overlap_host_s,
            "serial_host_s": self._serial_host_s,
            "materialize_wait_s": self._materialize_wait_s,
            "dispatch_s": self._dispatch_s,
            "overlap_fraction":
                self._overlap_host_s / total if total > 0 else 0.0,
        }

    # ------------------------------------------------------------------
    # Run loops
    # ------------------------------------------------------------------
    def _wait_next_arrival(self) -> None:
        """Idle until the head queued request arrives (or a new submit
        wakes us) — an exact condition-variable wait, not a capped
        ``time.sleep`` poll, so sub-50ms arrival gaps cost exactly the
        gap."""
        wait = self.queue[0].arrival_time - self._now()
        if wait > 0:
            with self._wake:
                self._wake.wait(timeout=wait)

    def run(self, rng) -> Dict[str, Response]:
        """Drain the queue and all live slots; returns id -> Response."""
        self._t0 = time.perf_counter()
        if not self.sync:
            return self._run_async(rng)
        while self.queue or self.pool.num_live:
            if self.pool.num_live == 0 and not self._ready(self._now()):
                self._wait_next_arrival()     # idle until the next arrival
                continue
            rng, k1, k2 = jax.random.split(rng, 3)
            self._step_sync(k1, k2)
        return dict(self.responses)

    def _run_async(self, rng) -> Dict[str, Response]:
        """Pipelined drain: rng is split once per *dispatched* engine
        step (never on drain-only iterations), keeping the per-step key
        sequence identical to the synchronous loop's."""
        while (self.queue or self.pool.num_live or self.has_pending):
            now = self._now()
            if (self.pool.num_live == 0 and not self.has_pending
                    and not self._ready(now)):
                self._wait_next_arrival()
                continue
            self._pump(now)
            if self.pool.num_live:
                rng, k1, k2 = jax.random.split(rng, 3)
                self._dispatch(k1, k2)
            else:
                self.flush()
        return dict(self.responses)
