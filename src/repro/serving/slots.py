"""Slot-pool KV-cache management for the continuous-batching scheduler.

The engine's state is a fixed-capacity batch: every row ("slot") owns one
row of each of the three committed caches (draft pi_S, target pi_B, PRM),
``pos``/``pending``/``done`` bookkeeping, and — while occupied — one live
request.  :class:`SlotPool` is the host-side ledger mapping slots to
request ids; the array-level work (zeroing freed rows, masked prompt
prefill) lives in ``serving/engine.py::reset_cache_rows`` and
``GSIServingEngine._admit``.

Why slots are safe to reuse without re-allocating caches: the decode
attention mask only admits cache positions ``<= pos``, so after a slot's
``pos`` is reset to 0 the previous occupant's KV is invisible and gets
overwritten as the new request advances; recurrent/RWKV state and ring
buffers are explicitly zeroed by ``reset_cache_rows``.

Under the paged cache a slot no longer *owns* its rows: its block table
may splice in pages shared with other slots (or retained by the radix
prefix cache), so freeing a slot decrements per-page refcounts in
:class:`~repro.serving.pages.PagePool` — never zeroes shared rows.
``pack_tails`` builds the tail-only prefill array for prefix-cache hits
(the matched prefix is spliced, not re-committed).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

PAD = 0


@dataclass
class SlotPool:
    """Fixed-capacity slot ledger: request id per slot (None = free)."""
    capacity: int
    slot_request: List[Optional[str]] = field(default=None)

    def __post_init__(self):
        """Start all-free and build the O(1) request-id -> slot map."""
        if self.slot_request is None:
            self.slot_request = [None] * self.capacity
        assert len(self.slot_request) == self.capacity
        # request-id -> slot index, kept in sync by claim/release so
        # slot_of is O(1) (it runs per finished request per step)
        self._slot_of: Dict[str, int] = {
            r: i for i, r in enumerate(self.slot_request) if r is not None}

    # -- queries -------------------------------------------------------
    def free_slots(self) -> List[int]:
        """Slot indices currently holding no request (ascending)."""
        return [i for i, r in enumerate(self.slot_request) if r is None]

    def live_slots(self) -> List[int]:
        """Slot indices currently occupied by a request (ascending)."""
        return [i for i, r in enumerate(self.slot_request) if r is not None]

    @property
    def num_free(self) -> int:
        """Number of free slots."""
        return len(self.free_slots())

    @property
    def num_live(self) -> int:
        """Number of occupied (decoding) slots."""
        return self.capacity - self.num_free

    def request_of(self, slot: int) -> Optional[str]:
        """Request id occupying ``slot`` (None when free)."""
        return self.slot_request[slot]

    def slot_of(self, request_id: str) -> Optional[int]:
        """Slot a live request occupies (None when not live); O(1)."""
        return self._slot_of.get(request_id)

    # -- transitions ---------------------------------------------------
    def claim(self, slot: int, request_id: str) -> None:
        """Bind a request id to a free slot (raises if occupied)."""
        if self.slot_request[slot] is not None:
            raise ValueError(f"slot {slot} already holds "
                             f"{self.slot_request[slot]!r}")
        self.slot_request[slot] = request_id
        self._slot_of[request_id] = slot

    def release(self, slot: int) -> str:
        """Free an occupied slot; returns the request id it held."""
        rid = self.slot_request[slot]
        if rid is None:
            raise ValueError(f"slot {slot} is already free")
        self.slot_request[slot] = None
        del self._slot_of[rid]
        return rid


def pack_tails(prompts: np.ndarray, starts: np.ndarray) -> np.ndarray:
    """Shift each packed prompt row left past its prefix-cache match.

    ``prompts``: (B, W) PAD-padded admission array; ``starts``: (B,) match
    lengths.  Row b of the result is ``prompts[b, starts[b]:]`` padded back
    to width W — the tail the engine actually prefills (``tails[b, 0]``
    seeds ``pending`` at position ``starts[b]``).  Width is preserved so
    hit-length jitter never retraces the jitted admit.
    """
    prompts = np.asarray(prompts, np.int32)
    starts = np.asarray(starts, np.int64)
    B, W = prompts.shape
    if not starts.any():
        return prompts
    tails = np.full((B, W), PAD, np.int32)
    for b in range(B):
        s = int(starts[b])
        if not 0 <= s < W:
            raise ValueError(f"start {s} outside prompt width {W}")
        tails[b, :W - s] = prompts[b, s:]
    return tails


def pack_prompts(prompts: Dict[int, np.ndarray], capacity: int,
                 pad_len: int) -> np.ndarray:
    """Build the (capacity, pad_len) admission array: slot -> prompt tokens,
    PAD everywhere else (non-admitted rows are inert under row_live)."""
    out = np.full((capacity, pad_len), PAD, np.int32)
    for slot, toks in prompts.items():
        toks = np.asarray(toks, np.int32)
        if toks.ndim != 1 or toks.size < 1:
            raise ValueError("prompt must be a non-empty 1-D token array")
        if toks.size > pad_len:
            raise ValueError(f"prompt length {toks.size} > pad_len {pad_len}")
        out[slot, :toks.size] = toks
    return out
