"""One data-parallel serving replica: an engine + scheduler pair.

A *replica* is the unit of data-parallel scale-out: one
:class:`~repro.serving.gsi_engine.GSIServingEngine` (its own jitted
phases, page pool and radix prefix index) driven by one
:class:`~repro.serving.scheduler.GSIScheduler` (its own queue, slot pool
and stats).  Replicas share nothing — no pages, no trie, no state — so a
fleet of them is exactly N independent copies of the single-engine
serving stack, and the only cross-replica component is the
:class:`~repro.serving.router.ReplicaRouter` that assigns requests.

Because the radix index is engine-held host state, *which* replica a
request lands on decides whether its prompt's preamble pages are already
cached there: the router's preamble-affinity policy exists to keep
requests with a common prefix on the replica that holds its pages.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

from repro.serving.gsi_engine import GSIServingEngine
from repro.serving.scheduler import GSIScheduler, Response


@dataclass
class Replica:
    """One router-fronted serving replica (engine + scheduler + id).

    ``index`` is the replica's stable position in the router's fleet (it
    is what the affinity hash maps to); ``scheduler`` owns the engine.
    ``routed`` counts lifetime requests assigned here (routing stats).
    """

    index: int
    scheduler: GSIScheduler
    routed: int = 0

    @property
    def engine(self) -> GSIServingEngine:
        """The replica's engine (owns this replica's pages and trie)."""
        return self.scheduler.engine

    @property
    def load(self) -> int:
        """Outstanding work: queued requests + live (decoding) slots.

        This is the quantity the router's least-loaded policy and the
        affinity policy's skew guard compare across replicas.
        """
        return len(self.scheduler.queue) + self.scheduler.pool.num_live

    @property
    def has_work(self) -> bool:
        """True while anything is queued or decoding on this replica."""
        return bool(self.scheduler.queue) or \
            self.scheduler.pool.num_live > 0

    def next_arrival(self) -> Optional[float]:
        """Arrival time of the head queued request (None when empty)."""
        if not self.scheduler.queue:
            return None
        return float(self.scheduler.queue[0].arrival_time)

    def submit(self, prompt, *, request_id: str,
               max_steps: Optional[int] = None,
               arrival_time: float = 0.0) -> str:
        """Queue a routed request on this replica's scheduler."""
        self.routed += 1
        return self.scheduler.submit(prompt, request_id=request_id,
                                     max_steps=max_steps,
                                     arrival_time=arrival_time)

    def step(self, rng, rng_target=None) -> List[Response]:
        """One scheduler step (admit / decode / harvest) on this replica.

        A replica with no live slots and nothing ready to admit returns
        without running an engine step, so idle replicas cost nothing.
        """
        return self.scheduler.step(rng, rng_target)


def build_replicas(engines, *, capacity: int, continuous: bool = True,
                   prompt_pad_len: int = 0, collect_stats: bool = False,
                   cache_aware: bool = True) -> List[Replica]:
    """Wrap N independent engines into router-ready replicas.

    Each engine must be a distinct object: a paged engine backs one live
    state (its page allocator is engine-held host state), so replicas can
    never share one.  ``capacity`` is per replica — the fleet decodes
    ``len(engines) * capacity`` slots in total.  ``cache_aware`` turns on
    cache-aware admission ordering inside each replica (queued requests
    with live radix matches admit first).
    """
    engines = list(engines)
    if len(set(map(id, engines))) != len(engines):
        raise ValueError(
            "replicas must not share engine objects: a paged engine "
            "backs one live state at a time (one page pool, one radix "
            "index); build one engine per replica")
    return [
        Replica(i, GSIScheduler(eng, capacity=capacity,
                                continuous=continuous,
                                prompt_pad_len=prompt_pad_len,
                                collect_stats=collect_stats,
                                cache_aware=cache_aware))
        for i, eng in enumerate(engines)
    ]
