"""One data-parallel serving replica: an engine + scheduler pair.

A *replica* is the unit of data-parallel scale-out: one
:class:`~repro.serving.gsi_engine.GSIServingEngine` (its own jitted
phases, page pool and radix prefix index) driven by one
:class:`~repro.serving.scheduler.GSIScheduler` (its own queue, slot pool
and stats).  Replicas share nothing — no pages, no trie, no state — so a
fleet of them is exactly N independent copies of the single-engine
serving stack, and the only cross-replica component is the
:class:`~repro.serving.router.ReplicaRouter` that assigns requests.

Because the radix index is engine-held host state, *which* replica a
request lands on decides whether its prompt's preamble pages are already
cached there: the router's preamble-affinity policy exists to keep
requests with a common prefix on the replica that holds its pages.

Replicas compose with tensor parallelism: each engine may additionally
own a disjoint device *submesh* (``--mesh-shape`` /
:func:`repro.launch.mesh.carve_submeshes`) over which its target model
is sharded — data parallel *across* replicas, tensor parallel *within*
one.  The router checks the submeshes are homogeneous in shape and
mutually disjoint; all replica/scheduler logic here is mesh-agnostic
because the engine hides sharding behind its jitted phase surface.

For the thread-per-replica fleet loop each replica carries a thread-safe
*inbox*: ``submit`` only enqueues (any thread, no scheduler state
touched) and the thread driving the replica drains the inbox into the
scheduler before each admission round.  A replica also owns its rng
chain, seeded by ``fold_in(fleet_key, index)`` so its key sequence never
depends on how many peers it has or on thread interleaving.
"""
from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import jax

from repro.serving.gsi_engine import GSIServingEngine
from repro.serving.scheduler import GSIScheduler, Response


@dataclass
class Replica:
    """One router-fronted serving replica (engine + scheduler + id).

    ``index`` is the replica's stable position in the router's fleet (it
    is what the affinity hash maps to); ``scheduler`` owns the engine.
    ``routed`` counts lifetime requests assigned here (routing stats).
    ``inbox``/``cv`` are the thread-safe submit queue and wake signal of
    the fleet loop; only the thread driving the replica ever touches the
    scheduler itself.
    """

    index: int
    scheduler: GSIScheduler
    routed: int = 0
    inbox: deque = field(default_factory=deque, repr=False)
    cv: threading.Condition = field(default_factory=threading.Condition,
                                    repr=False, compare=False)
    _rng: Optional[jax.Array] = field(default=None, repr=False,
                                      compare=False)

    @property
    def engine(self) -> GSIServingEngine:
        """The replica's engine (owns this replica's pages and trie)."""
        return self.scheduler.engine

    @property
    def load(self) -> int:
        """Outstanding work: inbox + queued requests + live slots.

        This is the quantity the router's least-loaded policy and the
        affinity policy's skew guard compare across replicas.
        """
        return len(self.inbox) + len(self.scheduler.queue) \
            + self.scheduler.pool.num_live

    @property
    def cached_groups(self) -> List[Tuple[int, ...]]:
        """Preamble-group chunks this replica's radix cache holds.

        The first-chunk keys of the engine's radix root whose pages are
        currently cached (refcount-free) — the unit the router's
        ``add_replica`` cache migration moves.  Empty for a non-paged or
        cache-less engine.
        """
        pager = self.engine.pager
        if pager is None or pager.index is None:
            return []
        return [chunk for chunk in pager.index.groups()
                if pager.index.root.children[chunk].page in pager.cached]

    @property
    def has_work(self) -> bool:
        """True while anything is inboxed, queued, decoding or still in
        the scheduler's async pipeline on this replica."""
        return bool(self.inbox) or bool(self.scheduler.queue) \
            or self.scheduler.pool.num_live > 0 \
            or self.scheduler.has_pending

    def next_arrival(self) -> Optional[float]:
        """Earliest arrival time across inbox and queue (None if empty).

        The inbox is snapshotted under the replica lock — a concurrent
        ``submit`` appending mid-iteration would otherwise kill the
        fleet-loop thread with "deque mutated during iteration".
        """
        with self.cv:
            times = [item[3] for item in self.inbox]
        if self.scheduler.queue:
            times.append(float(self.scheduler.queue[0].arrival_time))
        return min(times) if times else None

    # -- submission (any thread) ---------------------------------------
    def submit(self, prompt, *, request_id: str,
               max_steps: Optional[int] = None,
               arrival_time: float = 0.0, priority: int = 0,
               deadline_s: Optional[float] = None,
               stream=None) -> str:
        """Enqueue a routed request on this replica's inbox (thread-safe)
        and wake the replica's fleet-loop thread if it is idle."""
        with self.cv:
            self.routed += 1
            self.inbox.append((prompt, request_id, max_steps,
                               float(arrival_time), int(priority),
                               deadline_s, stream))
            self.cv.notify_all()
        return request_id

    # -- driving (owner thread only) -----------------------------------
    def drain_inbox(self) -> int:
        """Move inboxed requests into the scheduler queue; returns the
        number drained.  Called only by the thread driving the replica."""
        moved = 0
        while True:
            with self.cv:
                if not self.inbox:
                    return moved
                (prompt, rid, max_steps, arrival, priority, deadline_s,
                 stream) = self.inbox.popleft()
            self.scheduler.submit(prompt, request_id=rid,
                                  max_steps=max_steps,
                                  arrival_time=arrival,
                                  priority=priority,
                                  deadline_s=deadline_s, stream=stream)
            moved += 1

    def seed_rng(self, fleet_key) -> None:
        """Derive this replica's independent rng chain from the fleet
        key: ``fold_in(key, index)`` — stable whatever the fleet size or
        thread schedule."""
        self._rng = jax.random.fold_in(fleet_key, self.index)

    def next_keys(self) -> Tuple[jax.Array, jax.Array]:
        """Advance the replica rng chain by one engine step (k1, k2)."""
        if self._rng is None:
            raise RuntimeError("seed_rng() must be called before stepping "
                               "a replica through its own rng chain")
        self._rng, k1, k2 = jax.random.split(self._rng, 3)
        return k1, k2

    def step(self, rng, rng_target=None) -> List[Response]:
        """One scheduler step (admit / decode / harvest) on this replica.

        Drains the inbox first, so sequential (non-threaded) fleets see
        every routed request.  A replica with no live slots and nothing
        ready to admit returns without running an engine step, so idle
        replicas cost nothing.
        """
        self.drain_inbox()
        return self.scheduler.step(rng, rng_target)


def build_replicas(engines, *, capacity: int, continuous: bool = True,
                   prompt_pad_len: int = 0, collect_stats: bool = False,
                   cache_aware: bool = True, sync: bool = True,
                   chunk_tokens: int = 0) -> List[Replica]:
    """Wrap N independent engines into router-ready replicas.

    Each engine must be a distinct object: a paged engine backs one live
    state (its page allocator is engine-held host state), so replicas can
    never share one.  ``capacity`` is per replica — the fleet decodes
    ``len(engines) * capacity`` slots in total.  ``cache_aware`` turns on
    cache-aware admission ordering inside each replica (queued requests
    with live radix matches admit first); ``sync=False`` gives every
    replica the pipelined scheduler (one step ticket in flight);
    ``chunk_tokens`` sets every replica's per-step prefill budget
    (chunked prefill, 0 = unmetered).
    """
    engines = list(engines)
    if len(set(map(id, engines))) != len(engines):
        raise ValueError(
            "replicas must not share engine objects: a paged engine "
            "backs one live state at a time (one page pool, one radix "
            "index); build one engine per replica")
    return [
        Replica(i, GSIScheduler(eng, capacity=capacity,
                                continuous=continuous,
                                prompt_pad_len=prompt_pad_len,
                                collect_stats=collect_stats,
                                cache_aware=cache_aware,
                                sync=sync, chunk_tokens=chunk_tokens))
        for i, eng in enumerate(engines)
    ]
