"""Serving-engine primitives: cache batch expansion, candidate selection.

The GSI engine needs n scratch copies of a committed cache (one per draft
candidate).  Caches store the batch dim at position 0 (unstacked ``rem``
entries) or 1 (scan-stacked ``blocks`` entries); ``repeat_cache`` handles
both via path inspection, producing (B*n, ...) scratch caches laid out so
that row b*n+j is candidate j of request b.

In the *paged* layout, attention leaves are page pools ({'kp','vp'},
no batch dim) addressed through a per-slot block table, and candidate
branching is copy-on-write instead of dense duplication: ``branch_pages``
forks the table so the n branches alias the committed prefix's pages and
point their write range at statically reserved scratch pages, and
``branch_cache`` copies only the one partial page each branch will extend
— O(n * pages_per_step) pages instead of O(n * max_seq) rows.

Pages are refcounted (serving/pages.py) and may be aliased *across
requests* by the radix prefix cache, not just across a request's candidate
branches: everything here treats paged pool leaves as strictly read-only
shared storage — ``reset_cache_rows`` never zeroes them, branch writes land
only in scratch pages, and committed writes land only at ``pos``, which
admission guarantees is past every spliced (shared) page.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_PAGED_KEYS = ("kp", "vp", "ks", "vs")


def _batch_dim(path, stacked_key: str = "blocks") -> int:
    return 1 if any(getattr(p, "key", None) == stacked_key for p in path) \
        else 0


def _is_paged(path) -> bool:
    return any(getattr(p, "key", None) in _PAGED_KEYS for p in path)


def _is_stacked(path, stacked_key: str = "blocks") -> bool:
    return any(getattr(p, "key", None) == stacked_key for p in path)


def repeat_cache(cache, n: int, stacked_key: str = "blocks"):
    """Expand the batch dim B -> B*n (candidate-major rows)."""
    def rep(path, leaf):
        d = _batch_dim(path, stacked_key)
        return jnp.repeat(leaf, n, axis=d)
    return jax.tree_util.tree_map_with_path(rep, cache)


def reset_cache_rows(cache, reset_mask, stacked_key: str = "blocks"):
    """Zero the cache rows of requests where ``reset_mask`` (B,) is True.

    Used by the slot pool when a freed slot is re-admitted with a new
    prompt: attention KV beyond the reset ``pos`` is already masked out by
    the decode mask, but recurrent/RWKV state (and ring buffers) carry the
    previous occupant, so the whole row is cleared before prefill.  Paged
    pools ({'kp','vp'}) are shared across slots (and, with the radix prefix
    cache, across requests: freeing a slot merely decrements page refcounts
    on the host) and never need zeroing —
    a page is always written before the decode mask can expose it.
    """
    def zero(path, leaf):
        if _is_paged(path):
            return leaf
        d = _batch_dim(path, stacked_key)
        shape = [1] * leaf.ndim
        shape[d] = reset_mask.shape[0]
        m = reset_mask.reshape(shape)
        return jnp.where(m, jnp.zeros((), leaf.dtype), leaf)
    return jax.tree_util.tree_map_with_path(zero, cache)


# ---------------------------------------------------------------------------
# Paged copy-on-write candidate branching
# ---------------------------------------------------------------------------

def branch_pages(pt, pos, scratch_ids, page_size: int):
    """Fork the committed block table for n candidate branches.

    pt: (B, nblk1) committed table (last column is the trash block);
    pos: (B,); scratch_ids: (B, n, span) static scratch page ids.
    Returns the (B*n, nblk1) branch table: entries below the write block
    ``pos // page_size`` alias the committed prefix's pages; the ``span``
    entries from the write block on point at the branch's scratch pages
    (clamped into the trash column past the table end, where writes are
    discardable by construction).
    """
    B, n, span = scratch_ids.shape
    nblk1 = pt.shape[1]
    bpt = jnp.repeat(pt, n, axis=0)                       # (B*n, nblk1)
    blk0 = jnp.repeat(pos // page_size, n)                # (B*n,)
    rows = jnp.repeat(jnp.arange(B * n)[:, None], span, axis=1)
    cols = jnp.minimum(blk0[:, None] + jnp.arange(span)[None, :], nblk1 - 1)
    return bpt.at[rows, cols].set(scratch_ids.reshape(B * n, span))


def branch_cache(cache, n: int, pt, pos, scratch_ids, page_size: int,
                 stacked_key: str = "blocks"):
    """Copy-on-write analogue of ``repeat_cache`` for a paged cache.

    Paged pool leaves stay shared (aliased); only the partial page at the
    branch point is copied — each branch's first scratch page receives the
    content of the committed page holding ``pos``, so in-page committed
    rows below ``pos`` stay visible while branch writes land in scratch.
    Dense per-slot leaves (recurrent/RWKV state, cross KV) repeat as in
    the dense engine.
    """
    B = scratch_ids.shape[0]
    assert scratch_ids.shape[1] == n
    src = jnp.take_along_axis(pt, (pos // page_size)[:, None], axis=1)[:, 0]
    src = jnp.repeat(src, n)                              # (B*n,)
    dst = scratch_ids[:, :, 0].reshape(B * n)             # first scratch page

    def cow(path, leaf):
        if not _is_paged(path):
            d = _batch_dim(path, stacked_key)
            return jnp.repeat(leaf, n, axis=d)
        if _is_stacked(path, stacked_key):                # (reps, P, ps, ...)
            return leaf.at[:, dst].set(leaf[:, src])
        return leaf.at[dst].set(leaf[src])                # (P, ps, ...)

    return jax.tree_util.tree_map_with_path(cow, cache)


def paged_view(cache, pt, stacked_key: str = "blocks"):
    """Materialize the dense per-slot view of a paged cache.

    Gathers each pool leaf through the block table into the (B, S, KV, hd)
    layout the dense/score paths expect (S = nblk * page_size, absolute
    positions).  Quantized pools ({'ks','vs'} present) are dequantized on
    the way out — every row of logical block j carries block j's page
    scale — so consumers always see fp K/V.  Used by the shared-prefix
    scoring path and by tests; the hot decode path never builds this — it
    reads through ``kernels.ops.paged_attention`` /
    ``paged_attention_quant`` instead.
    """
    nblk = pt.shape[1]

    def gather(pool, sc=None):                            # (P, ps, KV, hd)
        P, ps = pool.shape[0], pool.shape[1]
        rows = (pt[:, :, None] * ps
                + jnp.arange(ps)[None, None, :]).reshape(pt.shape[0],
                                                         nblk * ps)
        flat = pool.reshape((P * ps,) + pool.shape[2:])
        out = jnp.take(flat, rows, axis=0)
        if sc is not None:                                # (P, KV) scales
            per_row = jnp.repeat(jnp.take(sc, pt, axis=0), ps, axis=1)
            out = out.astype(jnp.float32) * per_row[..., None]
        return out

    def walk(node, stacked):
        if isinstance(node, dict) and "kp" in node:
            out = {k: v for k, v in node.items()
                   if k not in _PAGED_KEYS}
            ks, vs = node.get("ks"), node.get("vs")
            if stacked:
                if ks is not None:
                    out["k"] = jax.vmap(gather)(node["kp"], ks)
                    out["v"] = jax.vmap(gather)(node["vp"], vs)
                else:
                    out["k"] = jax.vmap(gather)(node["kp"])
                    out["v"] = jax.vmap(gather)(node["vp"])
            else:
                out["k"] = gather(node["kp"], ks)
                out["v"] = gather(node["vp"], vs)
            return out
        if isinstance(node, dict):
            return {k: walk(v, stacked or k == stacked_key)
                    for k, v in node.items()}
        return node

    return walk(cache, False)


def expand_requests(x, n: int):
    """(B, ...) -> (B*n, ...) by repeating each request n times."""
    return jnp.repeat(x, n, axis=0)


def fold_candidates(x, n: int):
    """(B*n, ...) -> (B, n, ...)."""
    return x.reshape((x.shape[0] // n, n) + x.shape[1:])


def take_candidates(cands, idx):
    """cands: (B, n, L); idx: (B,) -> (B, L)."""
    return jnp.take_along_axis(cands, idx[:, None, None], axis=1)[:, 0]


def take_per_request(x, idx):
    """x: (B, n); idx: (B,) -> (B,)."""
    return jnp.take_along_axis(x, idx[:, None], axis=1)[:, 0]
