"""Serving-engine primitives: cache batch expansion, candidate selection.

The GSI engine needs n scratch copies of a committed cache (one per draft
candidate).  Caches store the batch dim at position 0 (unstacked ``rem``
entries) or 1 (scan-stacked ``blocks`` entries); ``repeat_cache`` handles
both via path inspection, producing (B*n, ...) scratch caches laid out so
that row b*n+j is candidate j of request b.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _batch_dim(path, stacked_key: str = "blocks") -> int:
    return 1 if any(getattr(p, "key", None) == stacked_key for p in path) \
        else 0


def repeat_cache(cache, n: int, stacked_key: str = "blocks"):
    """Expand the batch dim B -> B*n (candidate-major rows)."""
    def rep(path, leaf):
        d = _batch_dim(path, stacked_key)
        return jnp.repeat(leaf, n, axis=d)
    return jax.tree_util.tree_map_with_path(rep, cache)


def reset_cache_rows(cache, reset_mask, stacked_key: str = "blocks"):
    """Zero the cache rows of requests where ``reset_mask`` (B,) is True.

    Used by the slot pool when a freed slot is re-admitted with a new
    prompt: attention KV beyond the reset ``pos`` is already masked out by
    the decode mask, but recurrent/RWKV state (and ring buffers) carry the
    previous occupant, so the whole row is cleared before prefill.
    """
    def zero(path, leaf):
        d = _batch_dim(path, stacked_key)
        shape = [1] * leaf.ndim
        shape[d] = reset_mask.shape[0]
        m = reset_mask.reshape(shape)
        return jnp.where(m, jnp.zeros((), leaf.dtype), leaf)
    return jax.tree_util.tree_map_with_path(zero, cache)


def expand_requests(x, n: int):
    """(B, ...) -> (B*n, ...) by repeating each request n times."""
    return jnp.repeat(x, n, axis=0)


def fold_candidates(x, n: int):
    """(B*n, ...) -> (B, n, ...)."""
    return x.reshape((x.shape[0] // n, n) + x.shape[1:])


def take_candidates(cands, idx):
    """cands: (B, n, L); idx: (B,) -> (B, L)."""
    return jnp.take_along_axis(cands, idx[:, None, None], axis=1)[:, 0]


def take_per_request(x, idx):
    """x: (B, n); idx: (B,) -> (B,)."""
    return jnp.take_along_axis(x, idx[:, None], axis=1)[:, 0]
