"""Trace-time tensor-parallel context for the serving mesh mode.

The model code (``repro.models``) is written single-device: plain
einsums over whole weight tensors.  Under the engine's ``mesh=`` mode
the *target* model's attention/FFN/vocab weights arrive inside a
``shard_map`` body as **local shards** (global dim / tp).  Rather than
fork the model code, the engine traces the shard_map body inside a
:func:`tensor_parallel` context; the (few) model-side hooks call
:func:`axis` and, when it is set AND the tensor they hold is smaller
than the config says it should be, insert the collective that makes
the computation bitwise-identical to the unsharded one:

* row-parallel matmuls (attention ``wo``, FFN ``wo``) ``all_gather``
  both the sharded activation and the sharded weight and run the full
  matmul replicated — exact concatenation followed by the identical
  op on identical operands, so the result is bit-equal to unsharded
  (a psum-of-partials would reorder float additions and is not);
* the vocab-sharded embedding lookup masks out-of-shard token ids and
  ``psum``s (x + 0 == x, exact);
* the vocab-sharded unembed computes local logits and ``all_gather``s
  the vocab dim.

Replicated params (draft, PRM, and any target leaf the plan leaves
whole) match their config sizes, so every hook no-ops for them —
one shard_map body serves sharded and replicated models alike.

This module must stay import-light (jax only): it is imported by
``repro.models.common``/``attention`` and must not create a cycle
back into the models or serving packages.
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax

# Name of the mesh axis the current trace is sharded over (None =
# unsharded trace — every hook no-ops).
_AXIS: Optional[str] = None


def axis() -> Optional[str]:
    """The active tensor-parallel mesh axis name, or None."""
    return _AXIS


def axis_size() -> int:
    """Size of the active tp axis (1 when no context is active)."""
    if _AXIS is None:
        return 1
    return jax.lax.psum(1, _AXIS)


def shard_map_compat(fn, *, mesh, in_specs, out_specs):
    """``jax.shard_map`` (jax >= 0.6, check_vma) or the experimental API
    (jax 0.4.x, check_rep) — replication checking off in both, since the
    serving bodies mix sharded and replicated leaves freely."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(fn, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)


@contextlib.contextmanager
def tensor_parallel(axis_name: str = "model"):
    """Mark the enclosed trace as running inside a shard_map over
    ``axis_name``; model hooks become collective-aware for its scope."""
    global _AXIS
    prev = _AXIS
    _AXIS = axis_name
    try:
        yield
    finally:
        _AXIS = prev


def tp_plan(cfg, tp: int) -> dict:
    """Which weight groups of ``cfg`` can shard ``tp``-ways.

    Returns ``{"attn": bool, "mlp": bool, "vocab": bool}``.  Attention
    shards only when *both* the query heads and the kv heads divide
    ``tp`` (GQA grouping must stay aligned across q and kv shards);
    the MLP needs ``d_ff % tp == 0``; the embedding needs the *padded*
    vocab (multiple of 512) to divide.  Anything that doesn't divide
    stays replicated — sharding is always an optimisation, never a
    requirement.
    """
    if tp <= 1:
        return {"attn": False, "mlp": False, "vocab": False}
    from repro.models.common import padded_vocab
    heads_ok = (cfg.num_heads % tp == 0) and (cfg.num_kv_heads % tp == 0)
    return {
        "attn": heads_ok,
        "mlp": cfg.d_ff % tp == 0,
        "vocab": padded_vocab(cfg) % tp == 0,
    }
