"""Process-wide mesh context.

``jax.shard_map`` layers (MoE expert parallelism) need the active mesh at
trace time; launch scripts set it here so model code stays mesh-agnostic.
"""
from __future__ import annotations

import contextlib
from typing import Optional

_MESH = None


def set_mesh(mesh) -> None:
    """Install ``mesh`` as the process-wide active mesh (None clears)."""
    global _MESH
    _MESH = mesh


def get_mesh():
    """The process-wide active mesh, or None when unset."""
    return _MESH


@contextlib.contextmanager
def use_mesh(mesh):
    """Enter ``mesh`` (jax context manager + process-wide slot), restore
    the previous active mesh on exit."""
    global _MESH
    prev = _MESH
    _MESH = mesh
    try:
        with mesh:
            yield mesh
    finally:
        _MESH = prev
