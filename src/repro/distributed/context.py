"""Process-wide mesh context.

``jax.shard_map`` layers (MoE expert parallelism) need the active mesh at
trace time; launch scripts set it here so model code stays mesh-agnostic.
"""
from __future__ import annotations

import contextlib
from typing import Optional

_MESH = None


def set_mesh(mesh) -> None:
    global _MESH
    _MESH = mesh


def get_mesh():
    return _MESH


@contextlib.contextmanager
def use_mesh(mesh):
    global _MESH
    prev = _MESH
    _MESH = mesh
    try:
        with mesh:
            yield mesh
    finally:
        _MESH = prev
