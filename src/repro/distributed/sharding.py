"""Logical-axis -> mesh-axis sharding rules (FSDP / TP / EP / SP).

Parameters carry logical axis names (ParamSpec.axes); these rules map them to
mesh axes per run-mode, with automatic divisibility fallback (e.g. gemma3's
4 query heads cannot shard 16-way -> replicated, TP lands on mlp/vocab dims
instead).  Cache and input shardings are derived structurally: batch over
(pod, data) when divisible, otherwise sequence-parallel over 'data'
(long_500k's batch=1 KV cache).
"""
from __future__ import annotations

import math
import os
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.common import ParamSpec, is_param_spec

# logical axis -> preferred mesh axes, per mode
RULES = {
    "train": {
        "embed": ("data",),          # FSDP: shard weights over data axis
        "vocab": ("model",),
        "heads": ("model",),
        "kv": ("model",),
        "head": None,
        "mlp": ("model",),
        "mlp_out": None,
        "heads_flat": ("model",),
        "expert": ("model",),        # expert parallelism
        "expert_in": ("model",),
        "expert_mlp": ("data",),     # EP x FSDP for the 1T config
        "layer": None,
        None: None,
    },
    "serve": {
        "embed": None,               # weights replicated over data (serving)
        "vocab": ("model",),
        "heads": ("model",),
        "kv": ("model",),
        "head": None,
        "mlp": ("model",),
        "mlp_out": None,
        "heads_flat": ("model",),
        "expert": ("model",),
        "expert_in": ("model",),
        "expert_mlp": ("data",),     # kimi-scale: EP over model x data
        "layer": None,
        None: None,
    },
}


def _axes_size(mesh_shape: dict, axes) -> int:
    return math.prod(mesh_shape[a] for a in axes)


def spec_pspec(s: ParamSpec, mesh, mode: str) -> P:
    """PartitionSpec for one ParamSpec under the mode's rules.

    REPRO_EMBED_RULE=none overrides the train-mode FSDP rule (embed->data)
    to replication — the §Perf H1 experiment knob (GSPMD lowers
    contracting-dim-sharded weights into per-layer activation all-reduces;
    pure TP+DP avoids them at the cost of replicated weight memory).
    """
    import os
    rules = dict(RULES[mode])
    if mode == "train" and os.environ.get("REPRO_EMBED_RULE") == "none":
        rules["embed"] = None
    shape = dict(zip(mesh.axis_names, mesh.shape.values())) \
        if hasattr(mesh.shape, "values") else dict(
            zip(mesh.axis_names, mesh.devices.shape))
    entries = []
    used = set()
    for dim, logical in zip(s.shape, s.axes):
        axes = rules.get(logical)
        if axes and all(a in shape for a in axes) \
                and dim % _axes_size(shape, axes) == 0 \
                and not (set(axes) & used):
            entries.append(axes[0] if len(axes) == 1 else tuple(axes))
            used.update(axes)
        else:
            entries.append(None)
    return P(*entries)


def param_pspecs(spec_tree, mesh, mode: str):
    """PartitionSpec tree for a ParamSpec tree under the mode's rules."""
    return jax.tree.map(lambda s: spec_pspec(s, mesh, mode), spec_tree,
                        is_leaf=is_param_spec)


def param_shardings(spec_tree, mesh, mode: str):
    """NamedSharding tree for a ParamSpec tree under the mode's rules."""
    return jax.tree.map(lambda s: NamedSharding(mesh, spec_pspec(s, mesh,
                                                                 mode)),
                        spec_tree, is_leaf=is_param_spec)


# ---------------------------------------------------------------------------
# Serving-path tensor parallelism (path-gated, collect-then-compute)
# ---------------------------------------------------------------------------
#
# The generic RULES above map *logical axis names*; the serving engine
# instead needs a **path-gated** builder: the recurrent (lru) and rwkv
# families reuse the logical names "mlp" / "heads" / "heads_flat" on
# recurrence weights that have no gather hook in the model code, so a
# name-based rule would silently shard them and corrupt the math.  Only
# the three weight groups with trace-time collective hooks
# (repro.distributed.tp) may shard: dense attention (wq/wk/wv/wo),
# the dense FFN (wi_gate/wi_up/wo) and the embedding (embedding/unembed).

_ATTN_KEYS = ("wq", "wk", "wv", "wo")
_FFN_KEYS = ("wi_gate", "wi_up", "wo")
_EMBED_KEYS = ("embedding", "unembed")
# which logical axis carries the shard for each (group, leaf):
_SHARD_AXIS = {
    ("attn", "wq"): "heads", ("attn", "wk"): "kv", ("attn", "wv"): "kv",
    ("attn", "wo"): "heads",
    ("ffn", "wi_gate"): "mlp", ("ffn", "wi_up"): "mlp", ("ffn", "wo"): "mlp",
    ("embed", "embedding"): "vocab", ("embed", "unembed"): "vocab",
}


def _path_keys(path):
    return tuple(getattr(p, "key", getattr(p, "name", None)) for p in path)


def serve_target_pspecs(spec_tree, mesh, *, plan, axis: str = "model"):
    """PartitionSpec tree for the *target* model's params in mesh mode.

    ``plan`` is :func:`repro.distributed.tp.tp_plan`'s dict — a weight
    group shards only when its plan bit is set AND its shard dim divides
    the axis size.  Leaves outside the three hooked groups (recurrent /
    rwkv / moe / norms / reward head / time-mix) are replicated, whatever
    their logical axis names say.  The shard dim is found by *name* in
    ``ParamSpec.axes`` (layer-stacked leaves gain a leading "layer" axis,
    so positional indexing would be wrong).
    """
    sizes = mesh_axis_sizes(mesh)
    ways = sizes.get(axis, 1)

    def leaf_spec(path, s):
        keys = _path_keys(path)
        group = None
        for i, k in enumerate(keys):
            if k == "attn" and i + 1 < len(keys) \
                    and keys[i + 1] in _ATTN_KEYS:
                group = ("attn", keys[i + 1])
            elif k == "ffn" and i + 1 < len(keys) \
                    and keys[i + 1] in _FFN_KEYS:
                group = ("ffn", keys[i + 1])
            elif k == "embed" and i + 1 < len(keys) \
                    and keys[i + 1] in _EMBED_KEYS:
                group = ("embed", keys[i + 1])
        entries = [None] * len(s.shape)
        if group is not None and ways > 1:
            plan_key = {"attn": "attn", "ffn": "mlp",
                        "embed": "vocab"}[group[0]]
            logical = _SHARD_AXIS[group]
            if plan.get(plan_key) and logical in s.axes:
                dim = s.axes.index(logical)
                if s.shape[dim] % ways == 0:
                    entries[dim] = axis
        return P(*entries)

    return jax.tree_util.tree_map_with_path(leaf_spec, spec_tree,
                                            is_leaf=is_param_spec)


def serve_state_pspecs(state, mesh, *, shard_attn: bool,
                       target_key: str = "B", axis: str = "model"):
    """PartitionSpec tree for an engine state dict in mesh mode.

    Everything is replicated except the **target** model's attention KV
    leaves (``state["caches"][target_key]``), which shard along the
    kv-head axis when ``shard_attn`` and divisible:

    * paged pools ``kp``/``vp`` (P, ps, KV, hd) [stacked: (R, ...)] and
      dense ``k``/``v`` (B, S, KV, hd) shard dim ``ndim - 2``;
    * per-page quant scales ``ks``/``vs`` (P, KV) [stacked: (R, P, KV)]
      shard their last dim.

    Cross-attention (``ck``/``cv``), recurrent state, block tables,
    scratch, rng and the draft/PRM caches stay replicated — the draft
    speculates locally; only target scoring pays collectives.
    """
    sizes = mesh_axis_sizes(mesh)
    ways = sizes.get(axis, 1)

    def leaf_spec(path, leaf):
        entries = [None] * getattr(leaf, "ndim", 0)
        keys = _path_keys(path)
        if shard_attn and ways > 1 and "caches" in keys:
            ci = keys.index("caches")
            if ci + 1 < len(keys) and keys[ci + 1] == target_key:
                last = keys[-1]
                if last in ("kp", "vp", "k", "v") and leaf.ndim >= 4 \
                        and leaf.shape[-2] % ways == 0:
                    entries[leaf.ndim - 2] = axis
                elif last in ("ks", "vs") and leaf.ndim >= 2 \
                        and leaf.shape[-1] % ways == 0:
                    entries[leaf.ndim - 1] = axis
        return P(*entries)

    return jax.tree_util.tree_map_with_path(leaf_spec, state)


# ---------------------------------------------------------------------------
# Structural shardings for runtime arrays (caches, batches, opt state)
# ---------------------------------------------------------------------------

def mesh_axis_sizes(mesh) -> dict:
    """``{axis_name: size}`` for a mesh (works on fakes with .devices)."""
    return {a: s for a, s in zip(mesh.axis_names, mesh.devices.shape)}


def batch_pspec(mesh, batch: int):
    """Shard a leading batch dim over (pod,data) / data / nothing."""
    sizes = mesh_axis_sizes(mesh)
    cand = [ax for ax in (("pod", "data"), ("data",))
            if all(a in sizes for a in ax)]
    for axes in cand:
        if batch % _axes_size(sizes, axes) == 0:
            return axes if len(axes) > 1 else axes[0]
    return None


def data_batch_sharding(mesh, batch: int, ndim: int):
    """NamedSharding for (B, ...) host batches (tokens, masks)."""
    b = batch_pspec(mesh, batch)
    return NamedSharding(mesh, P(b, *([None] * (ndim - 1))))


def cache_pspecs(cache_shapes, mesh, *, stacked_key: str = "blocks"):
    """PartitionSpec tree for a decode cache (ShapeDtypeStruct tree).

    Attention caches (B,S,KV,hd) [stacked: (R,B,S,KV,hd)]: batch over
    (pod,)data when divisible; otherwise the sequence dim goes over 'data'
    (sequence parallelism).  KV-head dims shard over 'model' when divisible.
    Recurrent states (B,H,hd,hd)/(B,w): batch over data, head over model.
    """
    sizes = mesh_axis_sizes(mesh)
    model_ok = "model" in sizes

    def leaf_spec(path, leaf):
        is_stacked = any(getattr(p, "key", None) == stacked_key
                         for p in path)
        lead = 1 if is_stacked else 0
        shape = leaf.shape
        entries = [None] * len(shape)
        b = shape[lead]
        bspec = batch_pspec(mesh, b)
        if bspec is not None:
            entries[lead] = bspec
            data_used = True
        else:
            data_used = False
        # remaining dims: try model on a divisible "heads-like" dim;
        # for 4/5-D attention caches dim lead+1 is sequence.
        if len(shape) - lead >= 3:
            seq_dim = lead + 1
            head_dim = lead + 2
            seq_axes = []
            if model_ok and shape[head_dim] % sizes["model"] == 0 \
                    and shape[head_dim] > 1:
                entries[head_dim] = "model"
            elif model_ok and os.environ.get("REPRO_CACHE_SEQ_SHARD") == "1" \
                    and shape[seq_dim] % sizes["model"] == 0:
                # §Perf H2 iter-2: kv-heads not divisible by the model axis
                # (e.g. kimi kv=8 on a 16-way axis) -> sequence-shard the KV
                # cache over 'model' instead of replicating it.
                seq_axes.append("model")
            if not data_used and "data" in sizes \
                    and shape[seq_dim] % sizes["data"] == 0:
                seq_axes.append("data")     # sequence parallelism
            if seq_axes:
                entries[seq_dim] = (seq_axes[0] if len(seq_axes) == 1
                                    else tuple(seq_axes))
        return P(*entries)

    return jax.tree_util.tree_map_with_path(leaf_spec, cache_shapes)


def as_shardings(pspec_tree, mesh):
    """Map a PartitionSpec tree to NamedShardings on ``mesh``."""
    return jax.tree.map(lambda p: NamedSharding(mesh, p), pspec_tree,
                        is_leaf=lambda x: isinstance(x, P))
