"""AdamW + cosine schedule + global-norm clipping (pure JAX, optax-free)."""
from __future__ import annotations

import math
from typing import Callable

import jax
import jax.numpy as jnp

from repro.config import TrainConfig


def cosine_schedule(tcfg: TrainConfig) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = tcfg.learning_rate * step / max(1, tcfg.warmup_steps)
        frac = jnp.clip((step - tcfg.warmup_steps) /
                        max(1, tcfg.total_steps - tcfg.warmup_steps), 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * frac)) * tcfg.learning_rate
        return jnp.where(step < tcfg.warmup_steps, warm, cos)
    return lr


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), gn


class AdamW:
    """Stateless namespace: init / update over arbitrary param pytrees."""

    def __init__(self, tcfg: TrainConfig):
        self.cfg = tcfg
        self.lr_fn = cosine_schedule(tcfg)

    def init(self, params):
        dt = jnp.dtype(self.cfg.opt_state_dtype)
        zeros = lambda p: jnp.zeros(p.shape, dt)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(self, grads, state, params):
        c = self.cfg
        grads, gnorm = clip_by_global_norm(grads, c.grad_clip)
        count = state["count"] + 1
        b1, b2 = c.beta1, c.beta2
        dt = jnp.dtype(c.opt_state_dtype)

        def upd_m(m, g):
            return (b1 * m.astype(jnp.float32)
                    + (1 - b1) * g.astype(jnp.float32)).astype(dt)

        def upd_v(v, g):
            g32 = g.astype(jnp.float32)
            return (b2 * v.astype(jnp.float32)
                    + (1 - b2) * g32 * g32).astype(dt)

        m = jax.tree.map(upd_m, state["m"], grads)
        v = jax.tree.map(upd_v, state["v"], grads)
        lr = self.lr_fn(count)
        bc1 = 1 - b1 ** count.astype(jnp.float32)
        bc2 = 1 - b2 ** count.astype(jnp.float32)

        def step(p, mi, vi):
            mh = mi.astype(jnp.float32) / bc1
            vh = vi.astype(jnp.float32) / bc2
            delta = mh / (jnp.sqrt(vh) + c.eps) + c.weight_decay * \
                p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

        new_params = jax.tree.map(step, params, m, v)
        return new_params, {"m": m, "v": v, "count": count}, \
            {"grad_norm": gnorm, "lr": lr}
