"""Training loop: LM cross-entropy and PRM regression train steps.

``make_train_step`` returns a pure jittable (params, opt_state, batch) ->
(params, opt_state, metrics) function — the object that launch/dryrun.py
lowers with pjit shardings for the production meshes.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, TrainConfig
from repro.models import build_model
from repro.models.common import padded_vocab
from repro.optim import AdamW


def lm_loss(model, params, batch, *, source=None):
    """Next-token CE over loss_mask positions (+ MoE aux)."""
    tokens = batch["tokens"]
    mask = batch["loss_mask"][:, :-1]
    logits, aux = model.forward(params, tokens[:, :-1], source=source)
    labels = tokens[:, 1:]
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - picked
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    total = loss + model.cfg.router_aux_weight * aux
    return total, {"loss": loss, "aux_loss": aux,
                   "tokens": jnp.sum(mask)}


def prm_loss(model, params, batch):
    """BCE of the reward head vs golden process rewards at step ends."""
    tokens = batch["tokens"]
    r = model.reward(params, tokens)                   # (B,S)
    y = batch["reward_labels"]
    m = batch["reward_mask"]
    eps = 1e-6
    bce = -(y * jnp.log(r + eps) + (1 - y) * jnp.log(1 - r + eps))
    loss = jnp.sum(bce * m) / jnp.maximum(jnp.sum(m), 1.0)
    return loss, {"loss": loss, "aux_loss": jnp.zeros(()),
                  "tokens": jnp.sum(m)}


def _make_step(model, tcfg: TrainConfig, loss_fn) -> Callable:
    opt = AdamW(tcfg)

    def train_step(params, opt_state, batch):
        (_, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(model, p, batch), has_aux=True)(params)
        params, opt_state, opt_metrics = opt.update(grads, opt_state, params)
        metrics.update(opt_metrics)
        return params, opt_state, metrics

    return train_step


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig,
                    with_source: bool = False) -> Callable:
    model = build_model(cfg)
    if with_source:
        def loss(model, p, batch):
            return lm_loss(model, p,
                           {k: batch[k] for k in ("tokens", "loss_mask")},
                           source=batch["source"])
        return _make_step(model, tcfg, loss)
    return _make_step(model, tcfg, lm_loss)


def make_prm_train_step(cfg: ModelConfig, tcfg: TrainConfig) -> Callable:
    model = build_model(cfg)
    return _make_step(model, tcfg, prm_loss)


class Trainer:
    """Host-side convenience loop (single-process; used by examples/tests)."""

    def __init__(self, cfg: ModelConfig, tcfg: TrainConfig, *, prm=False):
        self.cfg = cfg
        self.tcfg = tcfg
        self.model = build_model(cfg)
        self.opt = AdamW(tcfg)
        self.params = self.model.init(jax.random.PRNGKey(tcfg.seed))
        self.opt_state = self.opt.init(self.params)
        step = (make_prm_train_step if prm else make_train_step)(cfg, tcfg)
        self._step = jax.jit(step, donate_argnums=(0, 1))
        self.history = []

    def fit(self, batches, steps: int, log_every: int = 50):
        import numpy as np
        for i, batch in enumerate(batches):
            if i >= steps:
                break
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            self.params, self.opt_state, m = self._step(
                self.params, self.opt_state, batch)
            if i % log_every == 0 or i == steps - 1:
                self.history.append(
                    {"step": i, "loss": float(m["loss"]),
                     "grad_norm": float(m["grad_norm"])})
        return self.history
