from repro.train.trainer import (  # noqa: F401
    make_train_step, make_prm_train_step, lm_loss, prm_loss, Trainer)
