"""Central configuration system for the repro framework.

Every model architecture is described by a :class:`ModelConfig`; input shapes
by :class:`ShapeConfig`; meshes by :class:`MeshConfig`; the GSI algorithm by
:class:`GSIConfig`.  Architecture configs register themselves into
``CONFIG_REGISTRY`` (see ``repro.configs``).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Callable, Optional

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------

# Layer kinds usable in ``layer_pattern``.
LAYER_FULL = "full"          # full causal self-attention
LAYER_LOCAL = "local"        # sliding-window causal self-attention
LAYER_RECURRENT = "recurrent"  # RG-LRU recurrent block (hybrid family)
LAYER_CROSS = "cross"        # self-attention + cross-attention (vlm / enc-dec)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads

    # --- MoE ----------------------------------------------------------------
    num_experts: int = 0             # routed experts (0 = dense FFN)
    experts_per_token: int = 0       # top-k
    num_shared_experts: int = 0      # always-on experts (qwen2-moe style)
    moe_d_ff: int = 0                # per-expert hidden dim (d_ff used if 0)
    capacity_factor: float = 1.25    # GShard dispatch capacity factor
    router_aux_weight: float = 0.01  # load-balance loss weight

    # --- layer pattern --------------------------------------------------------
    # The model is ``layer_pattern`` repeated; num_layers need not be a
    # multiple of len(layer_pattern): the remainder is the pattern prefix.
    layer_pattern: tuple = (LAYER_FULL,)
    window_size: int = 4096          # for LAYER_LOCAL

    # --- cross-modal ----------------------------------------------------------
    encoder_layers: int = 0          # audio encoder depth (enc-dec family)
    encoder_seq: int = 0             # #frames / #patches provided by the stub
    cross_source_seq: int = 0        # vlm: #patch embeddings

    # --- rwkv -----------------------------------------------------------------
    rwkv_head_dim: int = 64

    # --- hybrid (RG-LRU) -------------------------------------------------------
    lru_width: int = 0               # 0 -> d_model

    # --- misc -----------------------------------------------------------------
    rope_theta: float = 1.0e6
    norm_eps: float = 1.0e-6
    tie_embeddings: bool = True
    dtype: str = "bfloat16"          # activation dtype
    param_dtype: str = "bfloat16"
    logit_dtype: str = "float32"
    remat: str = "none"              # none | full | offloadable
    scan_layers: bool = True         # lax.scan over pattern blocks
    # serving variant: clamp attention to a sliding window (long-context decode
    # for dense archs; see DESIGN.md §4).
    serve_window_override: int = 0   # 0 = use layer kinds as-is

    # PRM head (reward models)
    reward_head: bool = False

    # source citation (model card / paper)
    source: str = ""

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.num_experts and self.moe_d_ff == 0:
            object.__setattr__(self, "moe_d_ff", self.d_ff)
        if self.lru_width == 0:
            object.__setattr__(self, "lru_width", self.d_model)

    # ---- derived quantities -------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def pattern_repeats(self) -> int:
        return self.num_layers // len(self.layer_pattern)

    @property
    def pattern_remainder(self) -> tuple:
        rem = self.num_layers % len(self.layer_pattern)
        return tuple(self.layer_pattern[:rem])

    def param_count(self) -> int:
        """Approximate parameter count (embedding + blocks)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        emb = v * d * (1 if self.tie_embeddings else 2)
        total = emb
        for kind in _expanded_pattern(self):
            attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
            if kind == LAYER_RECURRENT:
                w = self.lru_width
                blk = 2 * d * w + w * d + 2 * w * 4  # gates + in/out proj + conv-ish
            elif kind == LAYER_CROSS:
                blk = 2 * attn  # self + cross
            elif self.family == "ssm":
                hd = self.rwkv_head_dim
                blk = 4 * d * d + 6 * d  # r,k,v,o projections + decay/mix params
            else:
                blk = attn
            if self.num_experts:
                ffp = (self.num_experts + self.num_shared_experts) * 3 * d * self.moe_d_ff
                ffp += d * self.num_experts  # router
            else:
                ffp = 3 * d * ff
            if self.family == "ssm":
                ffp = 2 * d * int(3.5 * d)  # channel-mix
            total += blk + ffp
        # encoder stack (enc-dec)
        if self.encoder_layers:
            enc = self.encoder_layers * (4 * d * d + 3 * d * ff)
            total += enc
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed-in experts)."""
        if not self.num_experts:
            return self.param_count()
        dense_like = dataclasses.replace(
            self, num_experts=0, num_shared_experts=0,
            d_ff=(self.experts_per_token + self.num_shared_experts) * self.moe_d_ff)
        return dense_like.param_count()


def _expanded_pattern(cfg: ModelConfig):
    pat = list(cfg.layer_pattern)
    reps = cfg.pattern_repeats
    return pat * reps + list(cfg.pattern_remainder)


# ---------------------------------------------------------------------------
# Input shapes
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Mesh configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MeshConfig:
    shape: tuple
    axes: tuple

    @property
    def num_devices(self) -> int:
        return int(math.prod(self.shape))

    @property
    def batch_axes(self) -> tuple:
        return tuple(a for a in self.axes if a in ("pod", "data"))

    @property
    def model_axis_size(self) -> int:
        return self.shape[self.axes.index("model")]

    @property
    def data_axis_size(self) -> int:
        return int(math.prod(s for s, a in zip(self.shape, self.axes)
                             if a in ("pod", "data")))


SINGLE_POD = MeshConfig((16, 16), ("data", "model"))
MULTI_POD = MeshConfig((2, 16, 16), ("pod", "data", "model"))


# ---------------------------------------------------------------------------
# GSI / algorithm configuration (paper §5 hyperparameters)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class GSIConfig:
    n: int = 4                  # samples per reasoning step (draft side)
    n_target: int = 0           # resampling-side n (0 = same as n).  The
                                # paper flags decoupling these as future
                                # work (§4); see EXPERIMENTS §Beyond-paper.
    beta: float = 20.0          # inverse temperature (paper default)
    threshold_u: float = 0.5    # acceptance threshold on tilted reward
    temperature: float = 0.7    # sampling temperature
    top_p: float = 1.0
    max_step_tokens: int = 64   # max tokens per reasoning step (paper: 512)
    max_steps: int = 16         # max reasoning steps (paper: 45 / 100)
    sep_token_id: int = 1       # "\n\n" stand-in
    eos_token_id: int = 2
    min_step_reward: float = 0.1  # early-stop if all draft rewards below (B.2)
    use_rejection: bool = True  # False = "GSI w/o rejection" ablation


@dataclass(frozen=True)
class RSDConfig:
    n: int = 4
    beta: float = 20.0
    threshold: float = 0.7      # raw-reward acceptance threshold (Liao et al.)
    temperature: float = 0.7
    max_step_tokens: int = 64
    max_steps: int = 16
    sep_token_id: int = 1
    eos_token_id: int = 2


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    opt_state_dtype: str = "float32"  # bf16 for the 1T config (DESIGN §5)
    seed: int = 0


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

CONFIG_REGISTRY: dict = {}


def register_config(cfg: ModelConfig) -> ModelConfig:
    CONFIG_REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    import repro.configs  # noqa: F401  (populates registry)
    if name not in CONFIG_REGISTRY:
        raise KeyError(f"unknown architecture {name!r}; known: "
                       f"{sorted(CONFIG_REGISTRY)}")
    return CONFIG_REGISTRY[name]


def list_configs() -> list:
    import repro.configs  # noqa: F401
    return sorted(CONFIG_REGISTRY)


def reduced_config(cfg: ModelConfig, *, layers: int = 2, d_model: int = 128,
                   vocab: int = 512, max_experts: int = 4) -> ModelConfig:
    """A tiny same-family variant for CPU smoke tests."""
    num_heads = max(2, min(4, cfg.num_heads))
    kv = max(1, min(cfg.num_kv_heads, num_heads))
    # keep the head structure divisible
    while num_heads % kv:
        kv -= 1
    pat = cfg.layer_pattern[:max(1, min(len(cfg.layer_pattern), layers))]
    changes = dict(
        name=cfg.name + "-smoke",
        num_layers=layers,
        d_model=d_model,
        num_heads=num_heads,
        num_kv_heads=kv,
        head_dim=d_model // num_heads,
        d_ff=int(d_model * 8 // 3) // 16 * 16 or 64,
        vocab_size=vocab,
        layer_pattern=pat,
        window_size=min(cfg.window_size, 64),
        rwkv_head_dim=min(cfg.rwkv_head_dim, d_model // num_heads),
        lru_width=d_model,
        dtype="float32",
        param_dtype="float32",
        scan_layers=cfg.scan_layers,
    )
    if cfg.num_experts:
        e = min(cfg.num_experts, max_experts)
        changes.update(
            num_experts=e,
            experts_per_token=min(cfg.experts_per_token, 2),
            num_shared_experts=min(cfg.num_shared_experts, 1),
            moe_d_ff=d_model // 2,
            # lossless capacity so decode == forward exactly in smoke tests
            capacity_factor=float(e),
        )
    if cfg.encoder_layers:
        changes.update(encoder_layers=2, encoder_seq=max(16, min(cfg.encoder_seq, 32)))
    if cfg.cross_source_seq:
        changes.update(cross_source_seq=32)
    return dataclasses.replace(cfg, **changes)
