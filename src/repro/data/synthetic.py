"""Synthetic arithmetic-chain reasoning task with an exact golden reward.

This is the stand-in for MATH500/GSM8K (DESIGN.md §6): the container has no
model checkpoints or datasets, so the paper's accuracy experiments are
reproduced *in structure* on a task where the golden reward r*(x, y) is
computable exactly.

Task: given m numbers, produce the running partial sums as reasoning steps:

    prompt : "a1 + a2 + ... + am ="
    step t : digits of (a1 + ... + a_{t+1})  followed by SEP
    final  : digits of the total followed by EOS

Golden (process) reward of a prefix of steps = fraction of steps so far that
are correct partial sums; a malformed step scores 0 from there on.  Accuracy
= the final answer (last step before EOS) equals the true total.

Vocabulary (token ids):
    0 PAD   1 SEP ("\\n\\n")   2 EOS   3 "+"   4 "="   5..14 digits 0-9
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np

PAD, SEP, EOS, PLUS, EQ = 0, 1, 2, 3, 4
D0 = 5            # token id of digit 0
VOCAB = 16        # padded a little


def digits_to_tokens(x: int) -> List[int]:
    return [D0 + int(c) for c in str(int(x))]


def tokens_to_int(toks) -> Optional[int]:
    ds = []
    for t in toks:
        if not (D0 <= t < D0 + 10):
            return None
        ds.append(str(t - D0))
    if not ds:
        return None
    return int("".join(ds))


@dataclass
class Problem:
    numbers: Tuple[int, ...]
    prompt: Tuple[int, ...]

    @property
    def total(self) -> int:
        return sum(self.numbers)

    def partial(self, t: int) -> int:
        return sum(self.numbers[: t + 2])

    @property
    def num_steps(self) -> int:
        return len(self.numbers) - 1


class SyntheticReasoningTask:
    """Generator + golden reward for the arithmetic-chain task."""

    def __init__(self, *, min_terms=3, max_terms=5, max_value=29, seed=0):
        self.min_terms = min_terms
        self.max_terms = max_terms
        self.max_value = max_value
        self.rng = np.random.default_rng(seed)

    # ------------------------------------------------------------------
    def sample_problem(self) -> Problem:
        m = int(self.rng.integers(self.min_terms, self.max_terms + 1))
        nums = tuple(int(self.rng.integers(1, self.max_value + 1))
                     for _ in range(m))
        prompt: List[int] = []
        for i, a in enumerate(nums):
            if i:
                prompt.append(PLUS)
            prompt.extend(digits_to_tokens(a))
        prompt.append(EQ)
        return Problem(nums, tuple(prompt))

    def solution_steps(self, prob: Problem) -> List[List[int]]:
        steps = []
        for t in range(prob.num_steps):
            s = digits_to_tokens(prob.partial(t))
            s.append(SEP if t < prob.num_steps - 1 else EOS)
            steps.append(s)
        return steps

    def full_sequence(self, prob: Problem) -> List[int]:
        seq = list(prob.prompt)
        for s in self.solution_steps(prob):
            seq.extend(s)
        return seq

    # ------------------------------------------------------------------
    # Golden reward r*(x, steps) in [0,1]
    # ------------------------------------------------------------------
    def split_steps(self, toks) -> List[List[int]]:
        steps, cur = [], []
        for t in toks:
            if t == PAD:
                continue
            cur.append(int(t))
            if t in (SEP, EOS):
                steps.append(cur)
                cur = []
        if cur:
            steps.append(cur)
        return steps

    def golden_reward(self, prob: Problem, step_tokens_so_far) -> float:
        """Fraction of emitted steps that are correct partial sums."""
        steps = self.split_steps(step_tokens_so_far)
        if not steps:
            return 0.0
        good = 0
        for t, s in enumerate(steps):
            body = [x for x in s if x not in (SEP, EOS)]
            val = tokens_to_int(body)
            if (t < prob.num_steps and val is not None
                    and val == prob.partial(t)):
                good += 1
            else:
                break
        return good / prob.num_steps

    def is_correct(self, prob: Problem, step_tokens) -> bool:
        steps = self.split_steps(step_tokens)
        if not steps or steps[-1][-1] != EOS:
            return False
        body = [x for x in steps[-1] if x not in (SEP, EOS)]
        return tokens_to_int(body) == prob.total

    # ------------------------------------------------------------------
    # LM training batches (next-token prediction over full solutions)
    # ------------------------------------------------------------------
    def lm_batch(self, batch: int, seq_len: int):
        toks = np.full((batch, seq_len), PAD, np.int32)
        mask = np.zeros((batch, seq_len), np.float32)
        for b in range(batch):
            seq = self.full_sequence(self.sample_problem())[:seq_len]
            toks[b, :len(seq)] = seq
            # supervise the solution region only (after EQ)
            eq = seq.index(EQ)
            mask[b, eq:len(seq) - 1] = 1.0
        return {"tokens": toks, "loss_mask": mask}

    # ------------------------------------------------------------------
    # PRM training batches: chains with injected errors + per-token labels
    # ------------------------------------------------------------------
    def prm_batch(self, batch: int, seq_len: int, error_rate=0.45):
        toks = np.full((batch, seq_len), PAD, np.int32)
        labels = np.zeros((batch, seq_len), np.float32)
        mask = np.zeros((batch, seq_len), np.float32)
        for b in range(batch):
            prob = self.sample_problem()
            seq = list(prob.prompt)
            steps = self.solution_steps(prob)
            correct_so_far = 0
            broken = False
            for t, s in enumerate(steps):
                s = list(s)
                if self.rng.random() < error_rate:
                    # corrupt one digit of the step
                    idx = int(self.rng.integers(0, max(1, len(s) - 1)))
                    s[idx] = D0 + int(self.rng.integers(0, 10))
                    val = tokens_to_int([x for x in s if x not in (SEP, EOS)])
                    if val != prob.partial(t):
                        broken = True
                if not broken:
                    correct_so_far += 1
                start = len(seq)
                seq.extend(s)
                if start + len(s) > seq_len:
                    break
                # label every token of the step with the prefix reward
                r = correct_so_far / prob.num_steps
                labels[b, start:start + len(s)] = r
                mask[b, start + len(s) - 1] = 1.0  # train on step-end tokens
            seq = seq[:seq_len]
            toks[b, :len(seq)] = seq
        return {"tokens": toks, "reward_labels": labels, "reward_mask": mask}
