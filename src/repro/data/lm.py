"""Generic LM data pipeline: packed random-token streams + host prefetch.

Used by the train_4k driver for architectures whose "real" corpus is out of
scope (the dry-run only needs shapes; smoke training uses the synthetic
reasoning task).  Implements the standard pieces a production pipeline has:
deterministic shard-aware sampling, packing, and a double-buffered prefetch
iterator.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import numpy as np


def lm_batches(vocab_size: int, batch: int, seq_len: int, *, seed=0,
               num_batches: Optional[int] = None,
               shard_index: int = 0, shard_count: int = 1) -> Iterator[dict]:
    """Deterministic stream of {tokens, loss_mask} batches."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, shard_index]))
    i = 0
    while num_batches is None or i < num_batches:
        local = batch // shard_count
        toks = rng.integers(3, vocab_size, (local, seq_len), dtype=np.int32)
        yield {"tokens": toks,
               "loss_mask": np.ones((local, seq_len), np.float32)}
        i += 1


def prefetch(it: Iterator[dict], depth: int = 2) -> Iterator[dict]:
    """Host-side double-buffering (overlaps data gen with device steps)."""
    q: queue.Queue = queue.Queue(maxsize=depth)
    stop = object()

    def worker():
        try:
            for item in it:
                q.put(item)
        finally:
            q.put(stop)

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    while True:
        item = q.get()
        if item is stop:
            return
        yield item
