from repro.data.synthetic import (  # noqa: F401
    SyntheticReasoningTask, VOCAB, PAD, SEP, EOS)
from repro.data.lm import lm_batches  # noqa: F401
