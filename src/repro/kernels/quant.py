"""Quantized KV-page numerics shared by the kernels and the cache owner.

One source of truth for the quantized paged-KV formats: which ``kv_dtype``
strings exist, what storage dtype and quantization range each maps to, and
the round/clip step that turns a scaled fp page into its stored form.

Scheme: *per-page, per-kv-head symmetric scales*.  A page pool shaped
``(P, ps, KV, hd)`` stores int8 (or fp8) codes; a companion scale tensor
shaped ``(P, KV)`` float32 holds one positive scale per (page, kv head),
with ``fp ≈ code * scale``.  Scales are chosen as ``amax / QMAX`` over the
*valid* rows of the page at write time, so a page is re-quantized whole on
every token append: exact for rows whose scale did not change
(``round(code) == code``), and bounded-error otherwise since per-page amax
only grows as rows fill in.

``"bf16"`` is the unquantized half-width mode (plain cast, no scale
tensor) — it is the baseline the "int8 halves page bytes" capacity claim
is measured against, since toy configs run fp32 activations.
"""
from __future__ import annotations

import jax.numpy as jnp

#: Accepted values for the serving-level ``kv_dtype`` switch.  ``None``
#: keeps pages in the activation dtype (the fp accuracy oracle).
KV_DTYPES = (None, "bf16", "int8", "fp8")

#: kv_dtype values that carry a companion scale tensor.
QUANTIZED = ("int8", "fp8")

#: Largest representable magnitude per quantized format: int8 clips to
#: +-127 (symmetric, -128 unused), float8_e4m3fn saturates at +-448.
QMAX = {"int8": 127.0, "fp8": 448.0}

#: Scale floor: an all-zero (page, head) slice still gets a positive
#: scale, so dequantization never divides by / multiplies with zero.
EPS = 1e-8


def validate_kv_dtype(kv_dtype):
    """Return ``kv_dtype`` if it is a known mode, else raise ValueError."""
    if kv_dtype not in KV_DTYPES:
        raise ValueError(f"unknown kv_dtype {kv_dtype!r}; "
                         f"choose from {KV_DTYPES}")
    return kv_dtype


def is_quantized(kv_dtype) -> bool:
    """True iff the mode stores codes + per-page scales (int8 / fp8)."""
    return kv_dtype in QUANTIZED


def pool_dtype(kv_dtype, fallback):
    """Storage dtype of the page pools for ``kv_dtype``.

    ``fallback`` is the activation dtype used when quantization is off
    (``kv_dtype is None``).  ``"fp8"`` requires a jax build that ships
    ``float8_e4m3fn`` — raised as a clear error rather than a silent
    downgrade.
    """
    validate_kv_dtype(kv_dtype)
    if kv_dtype is None:
        return jnp.dtype(fallback)
    if kv_dtype == "bf16":
        return jnp.dtype(jnp.bfloat16)
    if kv_dtype == "int8":
        return jnp.dtype(jnp.int8)
    f8 = getattr(jnp, "float8_e4m3fn", None)
    if f8 is None:
        raise ValueError("kv_dtype='fp8' needs jax.numpy.float8_e4m3fn, "
                         "which this jax build does not provide; use "
                         "'int8' instead")
    return jnp.dtype(f8)


def quantize_codes(x, dtype):
    """Round/clip an already-scaled fp array into storage codes.

    int8 rounds-to-nearest and clips to +-127; fp8 (or any float storage)
    is a saturating cast.  ``x`` must already be divided by the scale.
    """
    dtype = jnp.dtype(dtype)
    if dtype == jnp.int8:
        q = jnp.clip(jnp.round(x), -QMAX["int8"], QMAX["int8"])
        return q.astype(jnp.int8)
    return x.astype(dtype)
