"""Blockwise (flash) causal/sliding-window GQA attention Pallas kernel.

Used for train/prefill (the decode path is a single-row matvec XLA already
handles well).  Grid: (B, H, Sq/Qt, Sk/Kt), k innermost; online-softmax
accumulators (m, l, acc) live in VMEM scratch across the k sweep.  GQA is
expressed in the BlockSpec index maps: query head h reads kv head h // G, so
no repeated KV materialization.  Sliding windows additionally mask
``kpos <= qpos - window``; fully-masked tiles are skipped by zero-ing their
contribution (on TPU the grid is traversed regardless; the masked-out tiles
cost one matmul — acceptable at our block sizes and noted in EXPERIMENTS
§Perf).

Block sizes default to (128, 128): MXU-aligned, and VMEM footprint
(q + k + v + acc tiles) ~ 4 * 128 * hd * 4B ≈ 0.5 MB for hd=256.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window: int, qt: int, kt: int,
            num_kt: int, sq: int, sk: int):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, :, 0, :].astype(jnp.float32)      # (Qt, hd)
    k = k_ref[0, :, 0, :].astype(jnp.float32)      # (Kt, hd)
    v = v_ref[0, :, 0, :].astype(jnp.float32)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    qpos = qi * qt + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    kpos = kj * kt + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = (qpos < sq) & (kpos < sk)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG)

    m_old = m_ref[...]
    m_new = jnp.maximum(m_old, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_old - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kj == num_kt - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "scale", "qt", "kt",
                              "interpret"))
def flash_attention_pallas(q, k, v, *, causal=True, window=0, scale=None,
                           qt: int = 128, kt: int = 128,
                           interpret: bool = False):
    """q: (B,Sq,H,hd); k/v: (B,Sk,KV,hd) -> (B,Sq,H,hd)."""
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    if scale is None:
        scale = hd ** -0.5
    qt = min(qt, max(8, Sq))
    kt = min(kt, max(8, Sk))
    Sqp = (Sq + qt - 1) // qt * qt
    Skp = (Sk + kt - 1) // kt * kt
    if Sqp != Sq:
        q = jnp.pad(q, ((0, 0), (0, Sqp - Sq), (0, 0), (0, 0)))
    if Skp != Sk:
        k = jnp.pad(k, ((0, 0), (0, Skp - Sk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Skp - Sk), (0, 0), (0, 0)))
    num_kt = Skp // kt

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, window=window,
                          qt=qt, kt=kt, num_kt=num_kt, sq=Sq, sk=Sk),
        grid=(B, H, Sqp // qt, num_kt),
        in_specs=[
            pl.BlockSpec((1, qt, 1, hd), lambda b, h, i, j: (b, i, h, 0)),
            pl.BlockSpec((1, kt, 1, hd),
                         lambda b, h, i, j, g=G: (b, j, h // g, 0)),
            pl.BlockSpec((1, kt, 1, hd),
                         lambda b, h, i, j, g=G: (b, j, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, qt, 1, hd), lambda b, h, i, j: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sqp, H, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((qt,), jnp.float32),
            pltpu.VMEM((qt,), jnp.float32),
            pltpu.VMEM((qt, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :Sq]
