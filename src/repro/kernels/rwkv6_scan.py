"""Chunked WKV6 recurrence Pallas kernel (RWKV-6 time-mix inner loop).

Recurrence per head (hd x hd fp32 state S):

    out_t = r_t . (S + u * k_t (x) v_t)
    S     = diag(w_t) S + k_t (x) v_t

TPU adaptation (DESIGN.md §3): the GPU CUDA kernel parallelizes over
(B,H) thread blocks with S in registers; here the grid is (B*H, T/C) with S
in VMEM scratch, r/k/v/w streamed chunk-by-chunk (one HBM round-trip per
chunk instead of per step).  The inner loop is sequential over the chunk —
the data-dependent per-channel decay makes the parallel "divide by cumprod
of decays" form numerically unsafe (w can reach e^-54 per step), matching
the fp32-state choice of the reference CUDA kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, o_ref, sT_ref,
            s_ref, *, chunk: int, num_chunks: int):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        s_ref[...] = s0_ref[0]

    u = u_ref[0]                                 # (hd,)

    def step(t, _):
        rt = r_ref[0, t].astype(jnp.float32)     # (hd,)
        kt = k_ref[0, t].astype(jnp.float32)
        vt = v_ref[0, t].astype(jnp.float32)
        wt = w_ref[0, t].astype(jnp.float32)
        S = s_ref[...]                           # (hd, hd)
        kv = kt[:, None] * vt[None, :]
        out = jnp.sum((S + u[:, None] * kv) * rt[:, None], axis=0)
        o_ref[0, t] = out
        s_ref[...] = wt[:, None] * S + kv
        return 0

    jax.lax.fori_loop(0, chunk, step, 0)

    @pl.when(c == num_chunks - 1)
    def _finish():
        sT_ref[0] = s_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_scan_pallas(r, k, v, w, u, state, *, chunk: int = 64,
                      interpret: bool = False):
    """r,k,v,w: (B,T,H,hd); u: (H,hd); state: (B,H,hd,hd) fp32.

    Returns (out (B,T,H,hd) fp32, final state (B,H,hd,hd) fp32).
    """
    B, T, H, hd = r.shape
    chunk = min(chunk, T)
    Tp = (T + chunk - 1) // chunk * chunk

    def prep(a):
        a = jnp.moveaxis(a, 2, 1).reshape(B * H, T, hd)  # (BH, T, hd)
        if Tp != T:
            # pad with decay=1, k=0 -> state unchanged on padded steps
            pad_val = 1.0 if a is None else 0.0
            a = jnp.pad(a, ((0, 0), (0, Tp - T), (0, 0)),
                        constant_values=pad_val)
        return a

    rr, kk, vv = prep(r), prep(k), prep(v)
    ww = jnp.moveaxis(w, 2, 1).reshape(B * H, T, hd)
    if Tp != T:
        ww = jnp.pad(ww, ((0, 0), (0, Tp - T), (0, 0)), constant_values=1.0)
    uu = jnp.broadcast_to(u[None], (B, H, hd)).reshape(B * H, hd)
    s0 = state.reshape(B * H, hd, hd).astype(jnp.float32)
    num_chunks = Tp // chunk

    out, sT = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk, num_chunks=num_chunks),
        grid=(B * H, num_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, hd), lambda g, c: (g, c, 0)),
            pl.BlockSpec((1, chunk, hd), lambda g, c: (g, c, 0)),
            pl.BlockSpec((1, chunk, hd), lambda g, c: (g, c, 0)),
            pl.BlockSpec((1, chunk, hd), lambda g, c: (g, c, 0)),
            pl.BlockSpec((1, hd), lambda g, c: (g, 0)),
            pl.BlockSpec((1, hd, hd), lambda g, c: (g, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, hd), lambda g, c: (g, c, 0)),
            pl.BlockSpec((1, hd, hd), lambda g, c: (g, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, Tp, hd), jnp.float32),
            jax.ShapeDtypeStruct((B * H, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(rr, kk, vv, ww, uu, s0)

    out = jnp.moveaxis(out[:, :T].reshape(B, H, T, hd), 1, 2)
    return out, sT.reshape(B, H, hd, hd)
