"""Pallas TPU kernels for the perf-critical compute of GSI serving.

Each kernel lives in <name>.py (pl.pallas_call + explicit BlockSpec VMEM
tiling), with the jit'd dispatch wrapper in ops.py and the pure-jnp oracle in
ref.py.  Validated in interpret mode on CPU (tests/test_kernels.py).
"""
from repro.kernels import ops, ref  # noqa: F401
