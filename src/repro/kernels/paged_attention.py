"""Paged decode-attention Pallas kernel: gather K/V through the block table.

One query token per request against a paged KV cache.  The page pools stay
in HBM-resident arrays shaped ``(P, page_size, KV, hd)``; the kernel never
materializes the gathered ``(B, S, KV, hd)`` copy that the jnp oracle
builds.  Instead the block table is a *scalar-prefetch* operand
(``pltpu.PrefetchScalarGridSpec``): the K/V BlockSpec index maps read
``pt[b, i]`` to DMA exactly the physical page for logical block ``i`` of
request ``b`` — the gather happens in the grid indexing, not in compute.

Grid: ``(B, H, nblk)`` with the block sweep innermost; online-softmax
accumulators (m, l, acc) live in VMEM scratch across the sweep, as in
``flash_attention.py``.  GQA reads kv head ``h // G``.  Validity is the
absolute-layout decode mask: position ``kpos = i * ps + lane`` is live iff
``kpos <= pos[b]`` (and ``kpos > pos[b] - window`` for sliding-window
layers) — stale rows of partially-filled or recycled pages are masked, so
pages never need zeroing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(pt_ref, pos_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
            acc_ref, *, scale: float, window: int, ps: int, nblk: int):
    b = pl.program_id(0)
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0, :].astype(jnp.float32)          # (hd,)
    k = k_ref[0, :, 0, :].astype(jnp.float32)       # (ps, hd)
    v = v_ref[0, :, 0, :].astype(jnp.float32)

    s = jnp.dot(k, q[:, None], preferred_element_type=jnp.float32)[:, 0]
    s = s * scale                                    # (ps,)
    kpos = i * ps + jax.lax.broadcasted_iota(jnp.int32, (ps, 1), 0)[:, 0]
    pos = pos_ref[b]
    mask = kpos <= pos
    if window:
        mask &= kpos > pos - window
    s = jnp.where(mask, s, NEG)

    m_old = m_ref[0]
    m_new = jnp.maximum(m_old, jnp.max(s))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_old - m_new)
    l_ref[0] = l_ref[0] * alpha + jnp.sum(p)
    acc_ref[0, :] = acc_ref[0, :] * alpha + jnp.dot(
        p[None, :], v, preferred_element_type=jnp.float32)[0]
    m_ref[0] = m_new

    @pl.when(i == nblk - 1)
    def _finish():
        l = jnp.maximum(l_ref[0], 1e-30)
        o_ref[0, 0, :] = (acc_ref[0, :] / l).astype(o_ref.dtype)


def _quant_kernel(pt_ref, pos_ref, ks_ref, vs_ref, q_ref, k_ref, v_ref,
                  o_ref, m_ref, l_ref, acc_ref, *, scale: float,
                  window: int, ps: int, nblk: int, g: int):
    """Fused-dequant variant of ``_kernel``: K/V blocks arrive as int8
    (or fp8) codes and are scaled back to float32 in registers — the fp
    copy of the page is never written anywhere.  The per-page scales ride
    the same scalar-prefetch path as the block table, so the scale lookup
    ``ks[pt[b, i], h // G]`` is SMEM reads, not an HBM gather."""
    b = pl.program_id(0)
    h = pl.program_id(1)
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    page = pt_ref[b, i]
    ksc = ks_ref[page, h // g]
    vsc = vs_ref[page, h // g]
    q = q_ref[0, 0, :].astype(jnp.float32)          # (hd,)
    k = k_ref[0, :, 0, :].astype(jnp.float32) * ksc  # (ps, hd) dequant
    v = v_ref[0, :, 0, :].astype(jnp.float32) * vsc

    s = jnp.dot(k, q[:, None], preferred_element_type=jnp.float32)[:, 0]
    s = s * scale                                    # (ps,)
    kpos = i * ps + jax.lax.broadcasted_iota(jnp.int32, (ps, 1), 0)[:, 0]
    pos = pos_ref[b]
    mask = kpos <= pos
    if window:
        mask &= kpos > pos - window
    s = jnp.where(mask, s, NEG)

    m_old = m_ref[0]
    m_new = jnp.maximum(m_old, jnp.max(s))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_old - m_new)
    l_ref[0] = l_ref[0] * alpha + jnp.sum(p)
    acc_ref[0, :] = acc_ref[0, :] * alpha + jnp.dot(
        p[None, :], v, preferred_element_type=jnp.float32)[0]
    m_ref[0] = m_new

    @pl.when(i == nblk - 1)
    def _finish():
        l = jnp.maximum(l_ref[0], 1e-30)
        o_ref[0, 0, :] = (acc_ref[0, :] / l).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("window", "scale", "interpret"))
def paged_attention_quant_pallas(q, kp, vp, ks, vs, pt, pos, *, window=0,
                                 scale=None, interpret: bool = False):
    """q: (B,1,H,hd); kp/vp: (P,ps,KV,hd) codes; ks/vs: (P,KV) float32
    scales; pt: (B,nblk); pos: (B,).

    Same grid/BlockSpec structure as ``paged_attention_pallas`` with two
    extra scalar-prefetch operands (the scale tensors) consumed by the
    fused dequantization in ``_quant_kernel``.
    """
    B, _, H, hd = q.shape
    _, ps, KV, _ = kp.shape
    G = H // KV
    nblk = pt.shape[1]
    if scale is None:
        scale = hd ** -0.5
    q3 = q[:, 0]                                     # (B, H, hd)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,                       # pt, pos, ks, vs
        grid=(B, H, nblk),
        in_specs=[
            pl.BlockSpec((1, 1, hd),
                         lambda b, h, i, pt, pos, ks, vs: (b, h, 0)),
            pl.BlockSpec((1, ps, 1, hd),
                         lambda b, h, i, pt, pos, ks, vs, g=G:
                         (pt[b, i], 0, h // g, 0)),
            pl.BlockSpec((1, ps, 1, hd),
                         lambda b, h, i, pt, pos, ks, vs, g=G:
                         (pt[b, i], 0, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, hd),
                               lambda b, h, i, pt, pos, ks, vs: (b, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((8,), jnp.float32),           # m (row 0 used)
            pltpu.VMEM((8,), jnp.float32),           # l
            pltpu.VMEM((8, hd), jnp.float32),        # acc
        ],
    )
    out = pl.pallas_call(
        functools.partial(_quant_kernel, scale=scale, window=window,
                          ps=ps, nblk=nblk, g=G),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        interpret=interpret,
    )(pt.astype(jnp.int32), pos.astype(jnp.int32),
      ks.astype(jnp.float32), vs.astype(jnp.float32), q3, kp, vp)
    return out[:, None]


@functools.partial(jax.jit,
                   static_argnames=("window", "scale", "interpret"))
def paged_attention_pallas(q, kp, vp, pt, pos, *, window=0, scale=None,
                           interpret: bool = False):
    """q: (B,1,H,hd); kp/vp: (P,ps,KV,hd); pt: (B,nblk); pos: (B,)."""
    B, _, H, hd = q.shape
    _, ps, KV, _ = kp.shape
    G = H // KV
    nblk = pt.shape[1]
    if scale is None:
        scale = hd ** -0.5
    q3 = q[:, 0]                                     # (B, H, hd)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                       # pt, pos
        grid=(B, H, nblk),
        in_specs=[
            pl.BlockSpec((1, 1, hd), lambda b, h, i, pt, pos: (b, h, 0)),
            pl.BlockSpec((1, ps, 1, hd),
                         lambda b, h, i, pt, pos, g=G: (pt[b, i], 0,
                                                        h // g, 0)),
            pl.BlockSpec((1, ps, 1, hd),
                         lambda b, h, i, pt, pos, g=G: (pt[b, i], 0,
                                                        h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, hd), lambda b, h, i, pt, pos: (b, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((8,), jnp.float32),           # m (row 0 used)
            pltpu.VMEM((8,), jnp.float32),           # l
            pltpu.VMEM((8, hd), jnp.float32),        # acc
        ],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, window=window, ps=ps,
                          nblk=nblk),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        interpret=interpret,
    )(pt.astype(jnp.int32), pos.astype(jnp.int32), q3, kp, vp)
    return out[:, None]
