"""Pure-jnp oracles for every Pallas kernel (the correctness reference)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def logprob_gather_ref(h, w, labels, vocab_size: int):
    """log softmax(h @ w)[labels].

    h: (B,S,d); w: (d,V); labels: (B,S) int -> (B,S) float32.
    """
    logits = (h.astype(jnp.float32) @ w.astype(jnp.float32))
    v = logits.shape[-1]
    if vocab_size < v:
        mask = jnp.arange(v) < vocab_size
        logits = jnp.where(mask, logits, -1e30)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return picked - logz


def flash_attention_ref(q, k, v, *, causal=True, window=0, scale=None):
    """q: (B,Sq,H,hd); k/v: (B,Sk,KV,hd) -> (B,Sq,H,hd)."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    if scale is None:
        scale = hd ** -0.5
    qg = q.reshape(B, Sq, KV, G, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k,
                   preferred_element_type=jnp.float32) * scale
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", p, v)
    return out.reshape(B, Sq, H, hd)


def paged_attention_ref(q, kp, vp, pt, pos, *, window=0, scale=None):
    """Paged decode attention: gather K/V through the block table.

    q: (B,1,H,hd) single-token queries; kp/vp: (P,ps,KV,hd) page pools;
    pt: (B,nblk) int32 block table (logical block j of row b lives in
    page pt[b,j]); pos: (B,) per-request positions -> (B,1,H,hd).

    Logical layout is *absolute*: cache row j holds position j, so the
    validity mask is ``j <= pos`` (and ``j > pos - window`` for
    sliding-window layers).  For full-attention layers this is exactly the
    dense decode layout, so outputs are bit-identical to the dense path:
    masked rows contribute exp(-1e30 - m) == 0.0 to the softmax and
    0.0 * v to the weighted sum regardless of stale page content.
    """
    B, _, H, hd = q.shape
    P, ps, KV, _ = kp.shape
    nblk = pt.shape[1]
    S = nblk * ps
    if scale is None:
        scale = hd ** -0.5
    rows = (pt[:, :, None] * ps
            + jnp.arange(ps)[None, None, :]).reshape(B, S)   # (B, S)
    k = jnp.take(kp.reshape(P * ps, KV, hd), rows, axis=0)   # (B,S,KV,hd)
    v = jnp.take(vp.reshape(P * ps, KV, hd), rows, axis=0)
    slots = jnp.arange(S)[None, :]                           # (1, S)
    mask = slots <= pos[:, None]
    if window:
        mask &= slots > pos[:, None] - window
    # identical math/order to models.attention.gqa_attention, including
    # its REPRO_ATTN_SCORES_BF16 score-buffer knob (_score_dtype) — the
    # bit-identity with the dense path must survive the env switch
    import os
    sdt = jnp.bfloat16 if os.environ.get("REPRO_ATTN_SCORES_BF16") == "1" \
        else jnp.float32
    G = H // KV
    qg = q.reshape(B, 1, KV, G, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k,
                   preferred_element_type=sdt) * scale
    s = s.astype(jnp.float32) \
        + jnp.where(mask[:, None, None, None, :], 0.0, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", p, v)
    return out.reshape(B, 1, H, hd)


def paged_attention_quant_ref(q, kp, vp, ks, vs, pt, pos, *, window=0,
                              scale=None):
    """Quantized paged decode attention: dequantize while gathering.

    q: (B,1,H,hd) fp queries; kp/vp: (P,ps,KV,hd) int8 (or fp8) code
    pools; ks/vs: (P,KV) float32 per-page per-kv-head scales with
    ``fp ~= code * scale``; pt: (B,nblk) block table; pos: (B,) ->
    (B,1,H,hd).

    The *production* CPU path (``REPRO_USE_PALLAS=0``): same gather /
    mask / softmax structure as ``paged_attention_ref`` with the
    dequantization folded into the gather (codes -> f32 times the
    per-row page scale).  It matches the fused Pallas kernel to f32
    round-off (a single softmax vs the kernel's online rescaling); the
    bit-exact mirror of the kernel is
    :func:`paged_attention_quant_cell_ref`.
    """
    B, _, H, hd = q.shape
    P, ps, KV, _ = kp.shape
    nblk = pt.shape[1]
    S = nblk * ps
    if scale is None:
        scale = hd ** -0.5
    ptc = pt.astype(jnp.int32)
    rows = (ptc[:, :, None] * ps
            + jnp.arange(ps)[None, None, :]).reshape(B, S)   # (B, S)
    # per-row scales: every row of logical block j carries block j's
    # page scale -> (B, S, KV)
    sk = jnp.repeat(jnp.take(ks, ptc, axis=0), ps, axis=1)
    sv = jnp.repeat(jnp.take(vs, ptc, axis=0), ps, axis=1)
    k = jnp.take(kp.reshape(P * ps, KV, hd), rows,
                 axis=0).astype(jnp.float32) * sk[..., None]
    v = jnp.take(vp.reshape(P * ps, KV, hd), rows,
                 axis=0).astype(jnp.float32) * sv[..., None]
    slots = jnp.arange(S)[None, :]                           # (1, S)
    mask = slots <= pos[:, None]
    if window:
        mask &= slots > pos[:, None] - window
    G = H // KV
    qg = q.reshape(B, 1, KV, G, hd).astype(jnp.float32)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k,
                   preferred_element_type=jnp.float32) * scale
    s = s + jnp.where(mask[:, None, None, None, :], 0.0, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", p, v)
    return out.reshape(B, 1, H, hd).astype(q.dtype)


def paged_attention_quant_cell_ref(q, kp, vp, ks, vs, pt, pos, *, window=0,
                                   scale=None):
    """Bit-exact oracle for the fused-dequant Pallas kernel.

    Same signature as :func:`paged_attention_quant_ref`, but mirrors
    ``_quant_kernel`` *exactly*, cell by cell: one (request, head)
    online-softmax sweep over logical blocks per grid cell, same op
    structure and f32 intermediate order.  The per-cell structure is
    load-bearing for the bit-identity test in tests/test_quant.py: XLA's
    CPU backend picks reduction strategies by operand *shape*, so any
    batched (vmapped / einsum) formulation of the same math accumulates
    in a different order than the kernel's per-cell dots and drifts by a
    few ulps.  The unrolled graph compiles slowly (seconds to tens of
    seconds) — test oracle only, never dispatched by ``kernels.ops``.
    """
    B, _, H, hd = q.shape
    P, ps, KV, _ = kp.shape
    nblk = pt.shape[1]
    G = H // KV
    if scale is None:
        scale = hd ** -0.5
    ptc = pt.astype(jnp.int32)
    posc = pos.astype(jnp.int32)
    lanes = jnp.arange(ps, dtype=jnp.int32)

    def cell(b, h):
        qv = q[b, 0, h, :].astype(jnp.float32)            # (hd,)
        m = jnp.float32(-1e30)
        l = jnp.float32(0.0)
        acc = jnp.zeros((hd,), jnp.float32)
        for i in range(nblk):
            page = ptc[b, i]
            k = kp[page, :, h // G, :].astype(jnp.float32) \
                * ks[page, h // G]                        # (ps, hd)
            v = vp[page, :, h // G, :].astype(jnp.float32) \
                * vs[page, h // G]
            s = jnp.dot(k, qv[:, None],
                        preferred_element_type=jnp.float32)[:, 0] * scale
            kpos = i * ps + lanes
            mask = kpos <= posc[b]
            if window:
                mask &= kpos > posc[b] - window
            s = jnp.where(mask, s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s))
            p = jnp.exp(s - m_new)
            alpha = jnp.exp(m - m_new)
            l = l * alpha + jnp.sum(p)
            acc = acc * alpha + jnp.dot(
                p[None, :], v, preferred_element_type=jnp.float32)[0]
            m = m_new
        return acc / jnp.maximum(l, 1e-30)

    out = jnp.stack([jnp.stack([cell(b, h) for h in range(H)])
                     for b in range(B)])                  # (B, H, hd)
    return out[:, None].astype(q.dtype)


def rwkv6_scan_ref(r, k, v, w, u, state):
    """Sequential WKV6 recurrence.

    r,k,v,w: (B,T,H,hd); u: (H,hd); state: (B,H,hd,hd) fp32.
    Returns (out (B,T,H,hd) fp32, final state).
    """
    rf, kf, vf, wf = (a.astype(jnp.float32) for a in (r, k, v, w))
    uf = u.astype(jnp.float32)

    def step(S, inp):
        rt, kt, vt, wt = inp
        kv = kt[..., :, None] * vt[..., None, :]
        out = jnp.einsum("bhk,bhkn->bhn", rt, S + uf[None, :, :, None] * kv)
        S = wt[..., :, None] * S + kv
        return S, out

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (rf, kf, vf, wf))
    S, outs = jax.lax.scan(step, state.astype(jnp.float32), xs)
    return jnp.moveaxis(outs, 0, 1), S
