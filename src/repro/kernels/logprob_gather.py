"""Fused log-softmax + label-gather Pallas kernel — the GSI scoring op.

Computing log pi_B(y_i | x) for n draft steps is one forward pass plus, per
token, ``log_softmax(h @ W)[label]``.  Naively XLA materializes the full
(T, V) logits in HBM (V up to 262k for gemma3 — the logits tensor dwarfs the
activations).  This kernel streams W in vocab tiles through VMEM, keeping an
online logsumexp accumulator and the gathered label logit per token, so the
logits tensor never exists in HBM:

    per (token-tile i, vocab-tile j):   logits_ij = h_i @ W_j  (MXU)
    m, s   <- online max / sum-exp update     (VPU)
    picked <- sum(one_hot(label - j0) * logits_ij)

Output: picked - (m + log s).  Grid is (T/Tt, V/Vt) with the vocab dim
innermost; accumulators live in VMEM scratch across the j sweep.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(h_ref, w_ref, lab_ref, o_ref, m_ref, s_ref, p_ref, *,
            vt: int, vocab_size: int, num_vt: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        s_ref[...] = jnp.zeros_like(s_ref)
        p_ref[...] = jnp.full_like(p_ref, NEG)

    h = h_ref[...].astype(jnp.float32)          # (Tt, d)
    w = w_ref[...].astype(jnp.float32)          # (d, Vt)
    logits = jnp.dot(h, w, preferred_element_type=jnp.float32)  # (Tt, Vt)

    v0 = j * vt
    vidx = v0 + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    valid = vidx < vocab_size
    logits = jnp.where(valid, logits, NEG)

    # online logsumexp
    m_old = m_ref[...]
    m_new = jnp.maximum(m_old, jnp.max(logits, axis=-1))
    scale = jnp.exp(m_old - m_new)
    s_ref[...] = s_ref[...] * scale + jnp.sum(
        jnp.exp(logits - m_new[:, None]), axis=-1)
    m_ref[...] = m_new

    # gather the label logit if it falls in this vocab tile
    lab = lab_ref[...]                           # (Tt,)
    hit = vidx == lab[:, None]
    p_ref[...] = jnp.maximum(p_ref[...],
                             jnp.max(jnp.where(hit, logits, NEG), axis=-1))

    @pl.when(j == num_vt - 1)
    def _finish():
        o_ref[...] = p_ref[...] - (m_ref[...] + jnp.log(s_ref[...]))


@functools.partial(jax.jit,
                   static_argnames=("vocab_size", "tt", "vt", "interpret"))
def logprob_gather_pallas(h, w, labels, vocab_size: int, *, tt: int = 256,
                          vt: int = 2048, interpret: bool = False):
    """h: (B,S,d); w: (d,V); labels: (B,S) -> (B,S) fp32."""
    B, S, d = h.shape
    V = w.shape[1]
    T = B * S
    hf = h.reshape(T, d)
    lab = labels.reshape(T)
    tt = min(tt, T)
    vt = min(vt, V)
    # pad T to a multiple of tt
    Tp = (T + tt - 1) // tt * tt
    if Tp != T:
        hf = jnp.pad(hf, ((0, Tp - T), (0, 0)))
        lab = jnp.pad(lab, (0, Tp - T))
    num_vt = (V + vt - 1) // vt

    out = pl.pallas_call(
        functools.partial(_kernel, vt=vt, vocab_size=vocab_size,
                          num_vt=num_vt),
        grid=(Tp // tt, num_vt),
        in_specs=[
            pl.BlockSpec((tt, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d, vt), lambda i, j: (0, j)),
            pl.BlockSpec((tt,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((tt,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((Tp,), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((tt,), jnp.float32),
            pltpu.VMEM((tt,), jnp.float32),
            pltpu.VMEM((tt,), jnp.float32),
        ],
        interpret=interpret,
    )(hf, w, lab)
    return out[:T].reshape(B, S)
