"""Jit'd public wrappers for the Pallas kernels.

Dispatch policy (env ``REPRO_USE_PALLAS``):
  "0" (default)  — pure-jnp reference path (CPU, dry-run lowering)
  "1"            — Pallas kernels, compiled for TPU
  "interpret"    — Pallas kernels in interpret mode (CPU correctness tests)

Tensor-parallel serving note: under the mesh engine these wrappers run
*inside* ``shard_map``, so paged-attention gathers see the local KV-head
shard of each page pool (the KV-head dim is sharded over the ``model``
axis) — per-shard shapes, no collectives here; the output projections in
``repro.models`` all_gather afterwards.
"""
from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ref


def _mode() -> str:
    return os.environ.get("REPRO_USE_PALLAS", "0")


def _interpret() -> bool:
    return _mode() == "interpret"


# ---------------------------------------------------------------------------
# logprob_gather — the GSI scoring hot-spot
# ---------------------------------------------------------------------------

def logprob_gather(h, w, labels, vocab_size: int):
    """Fused log-softmax + label gather over the vocab dim.

    h: (B,S,d); w: (d,V); labels: (B,S) -> (B,S) fp32 log-probs.
    """
    if _mode() == "0":
        return ref.logprob_gather_ref(h, w, labels, vocab_size)
    from repro.kernels.logprob_gather import logprob_gather_pallas
    return logprob_gather_pallas(h, w, labels, vocab_size,
                                 interpret=_interpret())


# ---------------------------------------------------------------------------
# flash attention (prefill / train)
# ---------------------------------------------------------------------------

def flash_attention(q, k, v, *, causal=True, window=0, scale=None):
    """q: (B,Sq,H,hd); k/v: (B,Sk,KV,hd) -> (B,Sq,H,hd)."""
    if _mode() == "0":
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                       scale=scale)
    from repro.kernels.flash_attention import flash_attention_pallas
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  scale=scale, interpret=_interpret())


# ---------------------------------------------------------------------------
# paged decode attention (serving; gathers K/V through the block table)
# ---------------------------------------------------------------------------

def paged_attention(q, kp, vp, pt, pos, *, window=0, scale=None):
    """q: (B,1,H,hd); kp/vp: (P,ps,KV,hd); pt: (B,nblk); pos: (B,)."""
    if _mode() == "0":
        return ref.paged_attention_ref(q, kp, vp, pt, pos, window=window,
                                       scale=scale)
    from repro.kernels.paged_attention import paged_attention_pallas
    return paged_attention_pallas(q, kp, vp, pt, pos, window=window,
                                  scale=scale, interpret=_interpret())


def paged_attention_quant(q, kp, vp, ks, vs, pt, pos, *, window=0,
                          scale=None):
    """Quantized paged decode attention with fused dequantization.

    q: (B,1,H,hd); kp/vp: (P,ps,KV,hd) int8/fp8 codes; ks/vs: (P,KV)
    float32 per-page per-kv-head scales; pt: (B,nblk); pos: (B,).
    """
    if _mode() == "0":
        return ref.paged_attention_quant_ref(q, kp, vp, ks, vs, pt, pos,
                                             window=window, scale=scale)
    from repro.kernels.paged_attention import paged_attention_quant_pallas
    return paged_attention_quant_pallas(q, kp, vp, ks, vs, pt, pos,
                                        window=window, scale=scale,
                                        interpret=_interpret())


# ---------------------------------------------------------------------------
# RWKV6 chunked scan
# ---------------------------------------------------------------------------

def rwkv6_scan(r, k, v, w, u, state):
    """r,k,v,w: (B,T,H,hd); u: (H,hd); state: (B,H,hd,hd) fp32."""
    if _mode() == "0":
        return ref.rwkv6_scan_ref(r, k, v, w, u, state)
    from repro.kernels.rwkv6_scan import rwkv6_scan_pallas
    return rwkv6_scan_pallas(r, k, v, w, u, state, interpret=_interpret())
