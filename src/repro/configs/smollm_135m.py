"""SmolLM-135M — small llama-architecture dense decoder.

[hf:HuggingFaceTB/SmolLM-135M]  30L, d_model=576, 9H (GQA kv=3), d_ff=1536,
vocab=49152.  Canonical *draft* model in our GSI pairings; also the ~100M
scale used by the end-to-end training example.  long_500k via sliding-window
variant.
"""
from repro.config import ModelConfig, register_config

CONFIG = register_config(ModelConfig(
    name="smollm-135m",
    family="dense",
    num_layers=30,
    d_model=576,
    num_heads=9,
    num_kv_heads=3,
    d_ff=1536,
    vocab_size=49152,
    head_dim=64,
    rope_theta=1.0e4,
    source="hf:HuggingFaceTB/SmolLM-135M",
))
