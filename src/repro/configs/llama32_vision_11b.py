"""Llama-3.2-Vision 11B — dense decoder with interleaved cross-attention
image layers.

[hf:meta-llama/Llama-3.2-11B-Vision]  40L, d_model=4096, 32H (GQA kv=8),
d_ff=14336, vocab=128256.  Cross-attention layers every 5th position
(pattern index 3 -> layers 3, 8, 13, ...; 40 = 8*5 exactly).  The ViT vision
encoder + projector is STUBBED: input_specs() provides patch embeddings of
shape (batch, cross_source_seq, d_model).
"""
from repro.config import ModelConfig, register_config

CONFIG = register_config(ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    head_dim=128,
    layer_pattern=("full", "full", "full", "cross", "full"),
    cross_source_seq=6404,      # 4 tiles x 1601 patch embeddings
    rope_theta=5.0e5,
    tie_embeddings=False,
    source="hf:meta-llama/Llama-3.2-11B-Vision",
))
