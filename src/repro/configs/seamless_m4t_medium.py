"""SeamlessM4T-medium — encoder-decoder multimodal (audio) backbone.

[arXiv:2308.11596]  12L decoder + 12L encoder, d_model=1024, 16H (kv=16),
d_ff=4096, vocab=256206.  The mel-spectrogram + conformer feature frontend is
STUBBED: input_specs() provides precomputed frame embeddings of shape
(batch, encoder_seq, d_model); we implement the transformer encoder over the
frames and the text decoder with per-layer cross-attention (the layer that
GSI actually drives).
"""
from repro.config import ModelConfig, register_config

CONFIG = register_config(ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    num_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    head_dim=64,
    layer_pattern=("cross",),   # every decoder layer has cross-attention
    encoder_layers=12,
    encoder_seq=1024,           # precomputed audio frame embeddings
    tie_embeddings=False,
    source="arXiv:2308.11596 (SeamlessM4T)",
))
