"""DeepSeek-LLM 7B — llama-architecture dense decoder (MHA: kv = heads).

[arXiv:2401.02954]  30L, d_model=4096, 32H (kv=32), d_ff=11008, vocab=102400.
Canonical *target* model in our GSI pairings.  long_500k via sliding-window
variant.
"""
from repro.config import ModelConfig, register_config

CONFIG = register_config(ModelConfig(
    name="deepseek-7b",
    family="dense",
    num_layers=30,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    d_ff=11008,
    vocab_size=102400,
    head_dim=128,
    rope_theta=1.0e4,
    tie_embeddings=False,
    source="arXiv:2401.02954 (DeepSeek LLM)",
))
