"""Kimi K2 — trillion-parameter MoE (paper-table config).

[arXiv:2501.kimi2]  61L, d_model=7168, 64H (GQA kv=8), per-expert d_ff=2048,
vocab=163840, MoE 384 routed experts top-8 (+1 shared).  Expert-parallel over
the 'model' axis (384/16 = 24 experts per group); at serve time the expert
FFN dim is additionally sharded over 'data' so the 1T weights fit 256 chips.
Training state fits only on the multi-pod (512-chip) mesh — see DESIGN.md §5.
"""
from repro.config import ModelConfig, register_config

CONFIG = register_config(ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=163840,
    head_dim=112,
    num_experts=384,
    experts_per_token=8,
    num_shared_experts=1,
    moe_d_ff=2048,
    tie_embeddings=False,
    source="arXiv:2501.kimi2 (Kimi K2)",
))
