"""RWKV6 "Finch" 3B — attention-free SSM with data-dependent decay.

[arXiv:2404.05892]  32L, d_model=2560, d_ff=8960, vocab=65536.
Attention-free: decode state is O(1) per layer; long_500k runs natively.
"""
from repro.config import ModelConfig, register_config

CONFIG = register_config(ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    num_layers=32,
    d_model=2560,
    num_heads=40,            # 2560 / rwkv_head_dim(64)
    num_kv_heads=40,
    d_ff=8960,
    vocab_size=65536,
    head_dim=64,
    rwkv_head_dim=64,
    layer_pattern=("full",),  # unused by ssm family (single block kind)
    tie_embeddings=False,
    source="arXiv:2404.05892 (RWKV-6 Finch)",
))
