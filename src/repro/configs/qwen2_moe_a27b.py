"""Qwen1.5-MoE-A2.7B — 60 routed experts top-4 + 4 shared experts.

[hf:Qwen/Qwen1.5-MoE-A2.7B]  24L, d_model=2048, 16H (kv=16), per-expert
d_ff=1408, vocab=151936.  The HF card's shared-expert intermediate (5632) is
modelled as 4 shared experts of 1408.
"""
from repro.config import ModelConfig, register_config

CONFIG = register_config(ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=151936,
    head_dim=128,
    num_experts=60,
    experts_per_token=4,
    num_shared_experts=4,
    moe_d_ff=1408,
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
))
