"""Gemma-3 1B — dense decoder with 5:1 local:global attention, 128k context.

[hf:google/gemma-3-1b-pt]  26L, d_model=1152, 4H (GQA kv=1), d_ff=6912,
vocab=262144.  Pattern: 5 sliding-window (512) layers per global layer;
26 = 4*6 + 2 remainder local layers.  The huge vocab stresses the GSI
logprob-gather scoring kernel.  long_500k: local layers are native; the 4
global layers decode over the full cache (linear per token).
"""
from repro.config import ModelConfig, register_config

CONFIG = register_config(ModelConfig(
    name="gemma3-1b",
    family="dense",
    num_layers=26,
    d_model=1152,
    num_heads=4,
    num_kv_heads=1,
    d_ff=6912,
    vocab_size=262144,
    head_dim=256,
    layer_pattern=("local", "local", "local", "local", "local", "full"),
    window_size=512,
    rope_theta=1.0e6,
    source="hf:google/gemma-3-1b-pt",
))
