"""The paper's Qwen3 pair: draft 1.7B / target 14B (thinking mode disabled).

[Qwen Team 2025; paper §5]
"""
from repro.config import ModelConfig, register_config

DRAFT = register_config(ModelConfig(
    name="qwen3-1.7b",
    family="dense",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=6144,
    vocab_size=151936,
    head_dim=128,
    source="hf:Qwen/Qwen3-1.7B (paper draft model)",
))

TARGET = register_config(ModelConfig(
    name="qwen3-14b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=17408,
    vocab_size=151936,
    head_dim=128,
    tie_embeddings=False,
    source="hf:Qwen/Qwen3-14B (paper target model)",
))
