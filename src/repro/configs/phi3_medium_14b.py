"""Phi-3-medium 14B — dense RoPE/SwiGLU/GQA decoder.

[arXiv:2404.14219]  40L, d_model=5120, 40H (GQA kv=10), d_ff=17920,
vocab=100352.  Pure full attention: long_500k is served with the
sliding-window variant (serve_window_override) per DESIGN.md §4.
"""
from repro.config import ModelConfig, register_config

CONFIG = register_config(ModelConfig(
    name="phi3-medium-14b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=10,
    d_ff=17920,
    vocab_size=100352,
    head_dim=128,
    tie_embeddings=False,
    source="arXiv:2404.14219 (Phi-3)",
))
