"""The paper's Qwen2.5-Math triple: draft 1.5B / target 7B / PRM 7B.

[Qwen Team 2024; paper §5]  The PRM shares the 7B architecture plus a scalar
reward head (process rewards in [0,1]).
"""
from repro.config import ModelConfig, register_config

DRAFT = register_config(ModelConfig(
    name="qwen2.5-math-1.5b",
    family="dense",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    head_dim=128,
    source="hf:Qwen/Qwen2.5-Math-1.5B-Instruct (paper draft model)",
))

TARGET = register_config(ModelConfig(
    name="qwen2.5-math-7b",
    family="dense",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    head_dim=128,
    tie_embeddings=False,
    source="hf:Qwen/Qwen2.5-Math-7B-Instruct (paper target model)",
))

PRM = register_config(ModelConfig(
    name="qwen2.5-math-prm-7b",
    family="dense",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    head_dim=128,
    tie_embeddings=False,
    reward_head=True,
    source="hf:Qwen/Qwen2.5-Math-PRM-7B (paper PRM)",
))
