"""Architecture registry: importing this package registers every config."""
from repro.configs import (  # noqa: F401
    rwkv6_3b,
    recurrentgemma_9b,
    gemma3_1b,
    kimi_k2_1t_a32b,
    seamless_m4t_medium,
    llama32_vision_11b,
    qwen2_moe_a27b,
    phi3_medium_14b,
    deepseek_7b,
    smollm_135m,
    qwen25_math,
    qwen3,
)

# The ten architectures assigned to this paper (public pool).
ASSIGNED = (
    "rwkv6-3b",
    "recurrentgemma-9b",
    "gemma3-1b",
    "kimi-k2-1t-a32b",
    "seamless-m4t-medium",
    "llama-3.2-vision-11b",
    "qwen2-moe-a2.7b",
    "phi3-medium-14b",
    "deepseek-7b",
    "smollm-135m",
)

# The paper's own model triples (draft / target / PRM).
PAPER_MODELS = (
    "qwen2.5-math-1.5b",
    "qwen2.5-math-7b",
    "qwen2.5-math-prm-7b",
    "qwen3-1.7b",
    "qwen3-14b",
)
