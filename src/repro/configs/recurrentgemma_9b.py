"""RecurrentGemma-9B (Griffin) — RG-LRU recurrent blocks + local attention 1:2.

[arXiv:2402.19427]  38L, d_model=4096, 16H (GQA kv=1 = MQA), d_ff=12288,
vocab=256000.  Pattern: (recurrent, recurrent, local-attn) repeated;
38 = 12*3 + 2 remainder recurrent layers.  Sub-quadratic -> long_500k native.
"""
from repro.config import ModelConfig, register_config

CONFIG = register_config(ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    d_ff=12288,
    vocab_size=256000,
    head_dim=256,
    layer_pattern=("recurrent", "recurrent", "local"),
    window_size=2048,
    lru_width=4096,
    source="arXiv:2402.19427 (Griffin / RecurrentGemma)",
))
