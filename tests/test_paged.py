"""Paged KV-cache tests: allocator, COW branching, paged-vs-dense parity.

Layers of coverage:
  * PagePool ledger (claim / lazy ensure / release / exhaustion).
  * paged_attention oracle == dense decode attention bit-for-bit on full
    layers across ragged ``pos`` and page-boundary-straddling positions;
    Pallas kernel (interpret mode) vs the oracle.
  * branch_pages / branch_cache copy-on-write semantics.
  * End-to-end: paged engine reproduces the dense engine's committed
    tokens exactly (same rng, same prompts) through engine.run and
    GSIScheduler.run, on full-attention, sliding-window and hybrid
    recurrent stacks.
  * Scheduler back-pressure: queued requests are deferred (not dropped)
    when the page pool is exhausted and admitted once pages free.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import GSIConfig, ModelConfig
from repro.kernels import ref
from repro.models import build_model
from repro.models.attention import _decode_mask, gqa_attention
from repro.serving import (GSIScheduler, GSIServingEngine, PagePool,
                           branch_cache, branch_pages, paged_view)

PAD = 0


def _triple(draft):
    target = dataclasses.replace(draft, name=draft.name + "-t", num_layers=3)
    prm = dataclasses.replace(target, name=draft.name + "-p",
                              reward_head=True)
    params = (build_model(draft).init(jax.random.PRNGKey(0)),
              build_model(target).init(jax.random.PRNGKey(1)),
              build_model(prm).init(jax.random.PRNGKey(2)))
    return (draft, target, prm), params


@pytest.fixture(scope="module")
def gcfg():
    return GSIConfig(n=2, max_step_tokens=5, max_steps=3, beta=4.0,
                     min_step_reward=-1.0)


@pytest.fixture(scope="module")
def dense_triple(tiny_dense):
    return _triple(tiny_dense)


# ----------------------------------------------------------------------
# PagePool ledger
# ----------------------------------------------------------------------

def test_page_pool_claim_ensure_release():
    pool = PagePool(6, page_size=8)
    assert pool.can_claim(6) and not pool.can_claim(7)
    pool.claim(0, 4)
    assert pool.num_claimed == 4 and pool.num_assigned == 0
    assert not pool.can_claim(3)          # only 2 unclaimed left
    new = pool.ensure(0, 2)
    assert [b for b, _ in new] == [0, 1]
    assert pool.num_assigned == 2 and pool.num_claimed == 2
    assert pool.ensure(0, 2) == []        # already covered
    pool.claim(1, 2)
    with pytest.raises(ValueError):
        pool.claim(1, 1)                  # double claim
    with pytest.raises(ValueError):
        pool.claim(2, 1)                  # pool fully reserved
    assert pool.release(0) == 2           # 2 assigned pages returned
    assert pool.num_free == 6 and pool.can_claim(4)
    with pytest.raises(ValueError):
        pool.release(0)


def test_page_pool_over_ensure_raises():
    pool = PagePool(4, page_size=8)
    pool.claim(0, 1)
    with pytest.raises(ValueError):
        pool.ensure(0, 2)                 # exceeds the slot's claim


# ----------------------------------------------------------------------
# Oracle and kernel
# ----------------------------------------------------------------------

@pytest.mark.parametrize("pos", [0, 7, 8, 9, 23, 39])   # page straddles
@pytest.mark.parametrize("window", [0, 11])             # + sliding window
def test_paged_oracle_matches_dense_bitwise(pos, window):
    """Paged decode == dense decode attention, bit for bit.

    The paged table scatters the logical rows across a shuffled pool;
    masked rows contribute exactly 0.0, so stale page content is
    irrelevant and the result is identical to the contiguous layout —
    for full layers and for the absolute-layout sliding-window mask.
    """
    B, H, KV, hd, ps, nblk = 2, 4, 2, 16, 8, 5
    S = nblk * ps
    ks = jax.random.split(jax.random.PRNGKey(pos), 3)
    q = jax.random.normal(ks[0], (B, 1, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    positions = jnp.array([pos, max(0, pos - 3)])

    # scatter the dense rows into a shuffled page pool
    P = B * nblk + 3
    perm = np.random.default_rng(pos).permutation(P)[:B * nblk]
    pt = jnp.asarray(perm.reshape(B, nblk))
    kp = jnp.zeros((P, ps, KV, hd))
    vp = jnp.zeros((P, ps, KV, hd))
    for b in range(B):
        for j in range(nblk):
            kp = kp.at[perm[b * nblk + j]].set(k[b, j * ps:(j + 1) * ps])
            vp = vp.at[perm[b * nblk + j]].set(v[b, j * ps:(j + 1) * ps])

    got = ref.paged_attention_ref(q, kp, vp, pt, positions, window=window)
    if window:
        slots = jnp.arange(S)[None, :]
        mask = ((slots <= positions[:, None])
                & (slots > positions[:, None] - window))[:, None]
    else:
        mask = _decode_mask(S, positions, ring=False)
    want = gqa_attention(q, k, v, mask, hd ** -0.5)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("B,H,KV,hd,ps,nblk,window", [
    (2, 4, 2, 16, 8, 4, 0),
    (1, 3, 1, 32, 16, 3, 0),
    (3, 4, 4, 16, 8, 5, 10),     # sliding window
    (2, 2, 2, 8, 4, 7, 6),       # window straddling many small pages
])
def test_paged_kernel_matches_oracle(B, H, KV, hd, ps, nblk, window):
    from repro.kernels.paged_attention import paged_attention_pallas
    P = B * nblk + 2
    ks = jax.random.split(jax.random.PRNGKey(B + hd + window), 4)
    q = jax.random.normal(ks[0], (B, 1, H, hd))
    kp = jax.random.normal(ks[1], (P, ps, KV, hd))
    vp = jax.random.normal(ks[2], (P, ps, KV, hd))
    pt = jax.random.randint(ks[3], (B, nblk), 0, P)
    # ragged positions incl. 0 and a page-boundary straddle
    pos = jnp.asarray(np.linspace(0, nblk * ps - 1, B).astype(np.int32))
    out = paged_attention_pallas(q, kp, vp, pt, pos, window=window,
                                 interpret=True)
    want = ref.paged_attention_ref(q, kp, vp, pt, pos, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=3e-6, rtol=3e-6)


def test_paged_oracle_respects_score_dtype_knob(monkeypatch):
    """REPRO_ATTN_SCORES_BF16=1 must flip the oracle's score buffers
    exactly like the dense path's _score_dtype(), preserving bit-identity."""
    monkeypatch.setenv("REPRO_ATTN_SCORES_BF16", "1")
    B, H, KV, hd, ps, nblk = 1, 2, 1, 16, 8, 2
    S = nblk * ps
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (B, 1, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    positions = jnp.array([11])
    pt = jnp.arange(nblk)[None]
    kp = k.reshape(nblk, ps, KV, hd)
    vp = v.reshape(nblk, ps, KV, hd)
    got = ref.paged_attention_ref(q, kp, vp, pt, positions)
    want = gqa_attention(q, k, v, _decode_mask(S, positions, ring=False),
                         hd ** -0.5)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_ops_dispatch_paged_interpret(monkeypatch):
    monkeypatch.setenv("REPRO_USE_PALLAS", "interpret")
    from repro.kernels import ops
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (1, 1, 2, 8))
    kp = jax.random.normal(ks[1], (4, 4, 2, 8))
    vp = jax.random.normal(ks[2], (4, 4, 2, 8))
    pt = jnp.array([[2, 0, 3]])
    pos = jnp.array([9])
    np.testing.assert_allclose(
        np.asarray(ops.paged_attention(q, kp, vp, pt, pos)),
        np.asarray(ref.paged_attention_ref(q, kp, vp, pt, pos)),
        atol=3e-6, rtol=3e-6)


# ----------------------------------------------------------------------
# Copy-on-write branching
# ----------------------------------------------------------------------

def test_branch_pages_aliases_prefix_and_redirects_writes():
    ps = 8
    pt = jnp.array([[3, 4, 5, 9], [6, 7, 9, 9]], jnp.int32)  # 9 = trash
    pos = jnp.array([12, 4])              # write blocks 1 and 0
    scratch = jnp.arange(10, 22, dtype=jnp.int32).reshape(2, 2, 3)
    bpt = np.asarray(branch_pages(pt, pos, scratch, ps))
    assert bpt.shape == (4, 4)
    # request 0 (blk0=1): committed block 0 aliased, blocks 1.. scratch
    np.testing.assert_array_equal(bpt[0], [3, 10, 11, 12])
    np.testing.assert_array_equal(bpt[1], [3, 13, 14, 15])
    # request 1 (blk0=0): every block scratch, trash column preserved
    np.testing.assert_array_equal(bpt[2], [16, 17, 18, 9])
    np.testing.assert_array_equal(bpt[3], [19, 20, 21, 9])


def test_branch_cache_cow_copies_only_partial_page(dense_triple, gcfg):
    cfgs, params = dense_triple
    eng = GSIServingEngine(*cfgs, *params, gcfg, max_seq=48, paged=True,
                           page_size=8)
    prompts = np.array([[5, 6, 7, 8, 9, 3, 2, 4, 11, 12, 13, 4]], np.int32)
    state = eng.init_state(prompts)       # pos = 11: page 1 is partial
    cache = state["caches"]["S"]
    scr = state["scratch"][:, :2]
    branched = branch_cache(cache, 2, state["pt"], state["pos"], scr,
                            eng.page_size)
    pt = np.asarray(state["pt"])
    blk0 = int(state["pos"][0]) // 8
    flat = jax.tree_util.tree_leaves(cache)
    bflat = jax.tree_util.tree_leaves(branched)
    for a, b in zip(flat, bflat):
        a, b = np.asarray(a), np.asarray(b)
        if a.shape != b.shape:            # dense leaf repeated
            continue
        # committed pages bit-identical in the branched pool
        for j in range(blk0 + 1):
            page = pt[0, j]
            if a.ndim == 4:               # (P, ps, KV, hd)
                np.testing.assert_array_equal(a[page], b[page])
            else:                         # (reps, P, ps, KV, hd)
                np.testing.assert_array_equal(a[:, page], b[:, page])
        # COW: each branch's first scratch page == committed partial page
        for jbr in range(2):
            s0 = int(np.asarray(scr)[0, jbr, 0])
            if a.ndim == 4:
                np.testing.assert_array_equal(b[s0], a[pt[0, blk0]])
            else:
                np.testing.assert_array_equal(b[:, s0], a[:, pt[0, blk0]])


def test_paged_view_matches_dense_cache(dense_triple, gcfg):
    """Gathering the pool through the table reproduces the dense cache on
    every committed position."""
    cfgs, params = dense_triple
    e0 = GSIServingEngine(*cfgs, *params, gcfg, max_seq=48)
    e1 = GSIServingEngine(*cfgs, *params, gcfg, max_seq=48, paged=True,
                          page_size=8)
    prompts = np.array([[5, 6, 7, 8, 9, 3, 4], [7, 3, 4, PAD, PAD, PAD,
                                                PAD]], np.int32)
    s0 = e0.init_state(prompts)
    s1 = e1.init_state(prompts)
    view = paged_view(s1["caches"]["S"], s1["pt"])
    pos = np.asarray(s0["pos"])
    d0 = jax.tree_util.tree_flatten_with_path(s0["caches"]["S"])[0]
    d1 = jax.tree_util.tree_flatten_with_path(view)[0]
    assert [p for p, _ in d0] == [p for p, _ in d1]
    for (path, a), (_, b) in zip(d0, d1):
        stacked = any(getattr(p, "key", None) == "blocks" for p in path)
        a, b = np.asarray(a), np.asarray(b)
        for r in range(prompts.shape[0]):
            rows_a = a[:, r] if stacked else a[r]
            rows_b = b[:, r] if stacked else b[r]
            seq_ax = 1 if stacked else 0
            sl = [slice(None)] * rows_a.ndim
            sl[seq_ax] = slice(0, int(pos[r]))
            np.testing.assert_array_equal(rows_a[tuple(sl)],
                                          rows_b[tuple(sl)])


# ----------------------------------------------------------------------
# End-to-end parity (the acceptance criterion)
# ----------------------------------------------------------------------

def _tokens(responses):
    return [[s.tolist() for s in r] for r in responses]


@pytest.mark.parametrize("pattern,window", [
    (("full",), 0),
    (("full", "local"), 12),
    (("recurrent", "full"), 0),
])
def test_paged_engine_run_matches_dense(gcfg, pattern, window):
    base = ModelConfig(
        name=f"t-pg-{'-'.join(pattern)}", family="dense"
        if "recurrent" not in pattern else "hybrid",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
        vocab_size=64, head_dim=16, dtype="float32", param_dtype="float32",
        layer_pattern=pattern, window_size=window or 4096)
    cfgs, params = _triple(base)
    e0 = GSIServingEngine(*cfgs, *params, gcfg, max_seq=48)
    e1 = GSIServingEngine(*cfgs, *params, gcfg, max_seq=48, paged=True,
                          page_size=8)
    prompts = np.array([[5, 6, 4], [7, 3, 4]], np.int32)
    r0, s0 = e0.run(prompts, jax.random.PRNGKey(3))
    r1, s1 = e1.run(prompts, jax.random.PRNGKey(3))
    assert _tokens(r0) == _tokens(r1)
    assert s0.steps == s1.steps


def test_paged_scheduler_run_matches_dense(dense_triple, gcfg):
    """Same rng, same prompts -> identical committed tokens through the
    continuous-batching scheduler with slot reuse."""
    cfgs, params = dense_triple
    outs = []
    for paged in (False, True):
        eng = GSIServingEngine(*cfgs, *params, gcfg, max_seq=48,
                               paged=paged, page_size=8)
        sched = GSIScheduler(eng, capacity=2)
        ids = [sched.submit([5, 6, 4]), sched.submit([7, 3, 4]),
               sched.submit([9, 9, 4], max_steps=2),
               sched.submit([11, 5, 4], max_steps=1)]
        out = sched.run(jax.random.PRNGKey(7))
        outs.append({r: out[r].tokens.tolist() for r in ids})
    assert outs[0] == outs[1]


def test_paged_modes_run(dense_triple, gcfg):
    """Every engine mode runs (and returns all pages) under paging."""
    cfgs, params = dense_triple
    for mode in ("gsi", "rsd", "sbon_s", "sbon_b", "gsi_norej"):
        eng = GSIServingEngine(*cfgs, *params, gcfg, mode=mode, max_seq=48,
                               paged=True, page_size=8)
        sched = GSIScheduler(eng, capacity=2)
        for _ in range(3):
            sched.submit([5, 6, 4], max_steps=2)
        out = sched.run(jax.random.PRNGKey(1))
        assert len(out) == 3, mode
        assert eng.pager.num_assigned == 0, mode     # all pages returned
        # decode-time publication may retain generated-trajectory pages
        # in the LRU set — free or cached, never leaked
        assert eng.pager.num_free + eng.pager.num_cached \
            == eng.num_pages, mode


# ----------------------------------------------------------------------
# Scheduler back-pressure on page exhaustion
# ----------------------------------------------------------------------

def test_scheduler_defers_on_page_exhaustion(dense_triple, gcfg):
    """With pages for only one request in flight, the second queued
    request must be deferred — not dropped — while slots are free, then
    admitted after the first finishes and returns its pages."""
    cfgs, params = dense_triple
    # blocks_needed(3, 3) = (2 + 15) // 8 + 1 = 3 pages; pool holds 3,
    # so only one of the two requests fits in flight at a time
    eng = GSIServingEngine(*cfgs, *params, gcfg, max_seq=48, paged=True,
                           page_size=8, num_pages=3)
    sched = GSIScheduler(eng, capacity=2)
    first = sched.submit([5, 6, 4], max_steps=3)
    second = sched.submit([7, 3, 4], max_steps=3)
    rng = jax.random.PRNGKey(0)
    done = []
    steps_to_first = 0
    while not done:                       # second deferred while first runs
        steps_to_first += 1
        rng, k = jax.random.split(rng)
        done = sched.step(k)
        assert len(sched.queue) == 1 and sched.queue[0].id == second
        assert sched.pool.num_free >= 1   # a free slot the whole time
    assert [r.request_id for r in done] == [first]
    done = []
    while not done:                       # pages freed -> admitted now
        rng, k = jax.random.split(rng)
        done = sched.step(k)
    assert [r.request_id for r in done] == [second]
    assert second in sched.responses      # deferred, not dropped
    # decode publication parks trajectory pages cached (evictable), so
    # the ledger — not an all-free pool — is the leak check
    assert eng.pager.num_free + eng.pager.num_cached == eng.num_pages


def test_stale_paged_state_raises(dense_triple, gcfg):
    """A paged engine backs one live state: stepping a state created
    before the latest fresh_state/init_state must raise, not silently
    hand its pages to the newer state."""
    cfgs, params = dense_triple
    eng = GSIServingEngine(*cfgs, *params, gcfg, max_seq=48, paged=True,
                           page_size=8)
    prompts = np.array([[5, 6, 4]], np.int32)
    old = eng.init_state(prompts)
    eng.init_state(prompts)               # invalidates `old`
    with pytest.raises(RuntimeError):
        eng.step_decode(old, jax.random.PRNGKey(0))


def test_scheduler_rejects_impossible_page_claim(dense_triple, gcfg):
    cfgs, params = dense_triple
    eng = GSIServingEngine(*cfgs, *params, gcfg, max_seq=48, paged=True,
                           page_size=8, num_pages=2)
    sched = GSIScheduler(eng, capacity=1)
    with pytest.raises(ValueError):
        sched.submit([5, 6, 4], max_steps=3)   # needs 3 pages forever


def test_released_slot_writes_cannot_corrupt_pages(dense_triple, gcfg):
    """After a request finishes and its pages are freed, the freed slot's
    table row is re-pointed at the trash page, so a recycled page owned by
    a newly admitted request stays intact."""
    cfgs, params = dense_triple
    eng = GSIServingEngine(*cfgs, *params, gcfg, max_seq=48, paged=True,
                           page_size=8, num_pages=3)
    sched = GSIScheduler(eng, capacity=2)
    sched.submit([5, 6, 4], max_steps=1)
    sched.submit([7, 3, 4], max_steps=3)
    rng = jax.random.PRNGKey(0)
    rng, k = jax.random.split(rng)
    sched.step(k)                         # first finishes, releases pages
    rng, k = jax.random.split(rng)
    sched.step(k)                         # second admitted onto its pages
    trash = eng._trash
    pt = np.asarray(sched.state["pt"])
    assert (pt[0] == trash).all() or sched.pool.request_of(0) is not None


# ----------------------------------------------------------------------
# Satellites: token accounting, slot_of O(1) sync, bounded stats
# ----------------------------------------------------------------------

def test_sbon_b_target_token_accounting(dense_triple, gcfg):
    """target_tokens must count the actual sampled candidate tokens, not
    chosen != PAD times n."""
    from repro.serving import EngineStats
    cfgs, params = dense_triple
    eng = GSIServingEngine(*cfgs, *params, gcfg, mode="sbon_b", max_seq=48)
    state = eng.init_state(np.array([[5, 6, 4], [7, 3, 4]], np.int32))
    stats = EngineStats()
    tp = eng._jit_target_phase(state, jax.random.PRNGKey(0))
    want = int(np.sum(np.asarray(tp["cands"]) != PAD))
    eng.step_decode(state, jax.random.PRNGKey(0),
                    jax.random.PRNGKey(1), stats=stats)
    assert stats.target_tokens == want


def test_slot_of_stays_in_sync():
    from repro.serving import SlotPool
    pool = SlotPool(3)
    pool.claim(2, "a")
    pool.claim(0, "b")
    assert pool.slot_of("a") == 2 and pool.slot_of("b") == 0
    pool.release(2)
    assert pool.slot_of("a") is None
    pool.claim(2, "c")
    assert pool.slot_of("c") == 2
    # reconstructed pools index existing occupancy
    pool2 = SlotPool(2, slot_request=[None, "x"])
    assert pool2.slot_of("x") == 1


def test_engine_stats_traces_bounded():
    from repro.serving import EngineStats
    stats = EngineStats(trace_limit=4)
    rng = np.random.default_rng(0)
    arrays = [rng.normal(size=(2, 3)) for _ in range(10)]
    for a in arrays:
        stats.record_trace("raw_rewards", a)
    assert len(stats.raw_rewards) == 4            # capped
    flat = np.concatenate([a.ravel() for a in arrays])
    assert stats.trace_count("raw_rewards") == flat.size
    np.testing.assert_allclose(stats.trace_mean("raw_rewards"),
                               flat.mean(), rtol=1e-12)
    np.testing.assert_allclose(stats.trace_var("raw_rewards"),
                               flat.var(), rtol=1e-9)
