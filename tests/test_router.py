"""Multi-replica router: placement, assembly, identity, cache-aware admission.

Layers of coverage:
  * Affinity placement is deterministic (hash tier is process-stable) and
    groups same-preamble requests onto one replica; the trie tier routes
    to the replica already holding a prompt's pages.
  * Least-loaded fallback under the skew guard spreads a hot preamble.
  * Responses are assembled id-keyed across replicas under out-of-order
    completion.
  * Greedy decoding: single-replica == multi-replica token identity
    (routing is a placement change, never an algorithm change).
  * Cache-aware admission ordering admits radix hits before cold prompts.
  * ``fresh_state()`` resets prefix counters with the radix index
    (the stale-hit-rate fix) on both scheduler and router.
"""
import jax
import numpy as np
import pytest

from repro.config import GSIConfig
from repro.models import build_model
from repro.serving import (GSIScheduler, GSIServingEngine, ReplicaRouter,
                           build_replicas, merge_engine_stats,
                           preamble_hash)
from repro.serving.gsi_engine import EngineStats

PAD = 0

# page_size=8 below: 2 full pages of preamble + 1 spill token
PRE_A = np.asarray([5 + (i % 24) for i in range(17)], np.int32)
PRE_B = np.asarray([30 + (i % 20) for i in range(17)], np.int32)


def _prompt(pre, tail):
    return np.concatenate([pre, np.asarray(tail, np.int32)])


@pytest.fixture(scope="module")
def triple(tiny_triple):
    draft, target, prm = tiny_triple
    params = (build_model(draft).init(jax.random.PRNGKey(0)),
              build_model(target).init(jax.random.PRNGKey(1)),
              build_model(prm).init(jax.random.PRNGKey(2)))
    return (draft, target, prm), params


@pytest.fixture(scope="module")
def gcfg():
    # temperature=0 (greedy): a request's trajectory is a function of its
    # prompt + budget only — independent of slot, step count, rng and
    # batch composition — which is what makes single- vs multi-replica
    # token identity assertable at all
    return GSIConfig(n=2, max_step_tokens=5, max_steps=3, beta=4.0,
                     min_step_reward=-1.0, temperature=0.0)


def _engine(triple, gcfg, **kw):
    (cfgs, params) = triple
    kw.setdefault("paged", True)
    kw.setdefault("page_size", 8)
    return GSIServingEngine(*cfgs, *params, gcfg, max_seq=96, **kw)


# ----------------------------------------------------------------------
# Placement
# ----------------------------------------------------------------------

def test_preamble_hash_deterministic_and_spread():
    chunk = list(range(16))
    assert preamble_hash(chunk, 4) == preamble_hash(np.asarray(chunk), 4)
    assert 0 <= preamble_hash(chunk, 4) < 4
    # different chunks do not all collapse onto one replica
    assert len({preamble_hash([c] * 16, 7) for c in range(1, 30)}) > 1


def test_affinity_groups_by_preamble_and_is_deterministic(triple, gcfg):
    prompts = [_prompt(PRE_A, [33, 34, 4]), _prompt(PRE_B, [35, 36, 4]),
               _prompt(PRE_A, [37, 38, 4]), _prompt(PRE_B, [39, 40, 4]),
               _prompt(PRE_A, [41, 42, 4]), _prompt(PRE_B, [43, 44, 4])]
    placements = []
    for _ in range(2):
        router = ReplicaRouter([_engine(triple, gcfg),
                                _engine(triple, gcfg)],
                               capacity=1, policy="affinity", skew=None)
        ids = [router.submit(p) for p in prompts]
        placements.append([router.replica_of(r) for r in ids])
    # deterministic run-to-run
    assert placements[0] == placements[1]
    # every request of a preamble group lands on one replica
    a_slots = {placements[0][i] for i in (0, 2, 4)}
    b_slots = {placements[0][i] for i in (1, 3, 5)}
    assert len(a_slots) == 1 and len(b_slots) == 1


def test_affinity_trie_tier_routes_to_cached_replica(triple, gcfg):
    router = ReplicaRouter([_engine(triple, gcfg), _engine(triple, gcfg)],
                           capacity=1, policy="affinity", skew=None)
    rid = router.submit(_prompt(PRE_A, [33, 34, 4]), max_steps=1)
    home = router.replica_of(rid)
    router.run(jax.random.PRNGKey(0))
    # preamble pages are now published on the home replica: the next
    # same-preamble request must match the trie (not just the hash)
    before = router.routing["affinity_matched"]
    assert router.route(_prompt(PRE_A, [35, 36, 4])) == home
    assert router.routing["affinity_matched"] == before + 1


def test_least_loaded_fallback_under_skew(triple, gcfg):
    router = ReplicaRouter([_engine(triple, gcfg), _engine(triple, gcfg)],
                           capacity=1, policy="affinity", skew=0)
    ids = [router.submit(_prompt(PRE_A, [33 + i, 34, 4])) for i in range(4)]
    placements = [router.replica_of(r) for r in ids]
    # skew=0: a replica may never lead by more than 0 at placement time,
    # so the hot preamble is spread across both replicas
    assert set(placements) == {0, 1}
    assert router.routing["fallback_load"] >= 1


def test_short_prompt_routes_least_loaded(triple, gcfg):
    router = ReplicaRouter([_engine(triple, gcfg), _engine(triple, gcfg)],
                           capacity=1, policy="affinity")
    # < 1 full page of shareable prefix: nothing to be affine to
    a = router.submit(np.asarray([5, 6, 4], np.int32))
    b = router.submit(np.asarray([7, 8, 4], np.int32))
    assert {router.replica_of(a), router.replica_of(b)} == {0, 1}
    assert router.routing["fallback_load"] == 2


def test_round_robin_cycles_and_duplicate_ids_rejected(triple, gcfg):
    router = ReplicaRouter([_engine(triple, gcfg), _engine(triple, gcfg)],
                           capacity=1, policy="round_robin")
    ids = [router.submit(_prompt(PRE_A, [33 + i, 34, 4]))
           for i in range(4)]
    assert [router.replica_of(r) for r in ids] == [0, 1, 0, 1]
    with pytest.raises(ValueError):
        router.submit(_prompt(PRE_A, [4]), request_id=ids[0])
    # generated ids skip ids a caller claimed explicitly
    router.submit(_prompt(PRE_A, [4]), request_id="req-4")
    nxt = router.submit(_prompt(PRE_A, [4]))
    assert nxt == "req-5"


def test_replicas_must_not_share_engines(triple, gcfg):
    eng = _engine(triple, gcfg)
    with pytest.raises(ValueError):
        build_replicas([eng, eng], capacity=1)


# ----------------------------------------------------------------------
# Assembly + identity
# ----------------------------------------------------------------------

def test_out_of_order_assembly_across_replicas(triple, gcfg):
    router = ReplicaRouter([_engine(triple, gcfg), _engine(triple, gcfg)],
                           capacity=1, policy="round_robin")
    budgets = {"long": 3, "s1": 1, "s2": 1, "s3": 1}
    for rid, b in budgets.items():
        router.submit(_prompt(PRE_A, [33, 34, 4]), request_id=rid,
                      max_steps=b)
    out = router.run(jax.random.PRNGKey(7))
    assert set(out) == set(budgets)
    for rid, b in budgets.items():
        assert out[rid].engine_steps == b, rid
        assert out[rid].finish_reason in ("max_steps", "eos", "low_reward")
    # short requests time-share replica 1 while "long" holds replica 0
    assert {router.replica_of(r) for r in budgets} == {0, 1}
    assert router.stats.requests_finished == 4


def test_single_replica_equals_multi_replica_tokens(triple, gcfg):
    prompts = [_prompt(PRE_A, [33, 34, 4]), _prompt(PRE_A, [35, 36, 4]),
               _prompt(PRE_B, [37, 38, 4]), _prompt(PRE_B, [39, 40, 4])]
    budgets = [1, 2, 1, 2]

    sched = GSIScheduler(_engine(triple, gcfg), capacity=1)
    ids = [sched.submit(p, request_id=f"r{i}", max_steps=budgets[i])
           for i, p in enumerate(prompts)]
    single = {r: resp.tokens.tolist()
              for r, resp in sched.run(jax.random.PRNGKey(3)).items()}

    for policy in ("affinity", "least_loaded"):
        router = ReplicaRouter([_engine(triple, gcfg),
                                _engine(triple, gcfg)],
                               capacity=1, policy=policy, skew=None)
        for i, p in enumerate(prompts):
            router.submit(p, request_id=f"r{i}", max_steps=budgets[i])
        multi = {r: resp.tokens.tolist()
                 for r, resp in router.run(jax.random.PRNGKey(91)).items()}
        assert multi == single, policy
    assert set(single) == set(ids)


def test_merge_engine_stats_sums_and_moments():
    a, b = EngineStats(), EngineStats()
    a.steps, b.steps = 3, 4
    a.prefix_hits, b.prefix_hits = 1, 2
    a.prefix_queries, b.prefix_queries = 2, 4
    a.record_trace("raw_rewards", np.asarray([1.0, 2.0]))
    b.record_trace("raw_rewards", np.asarray([3.0, 4.0, 5.0]))
    m = merge_engine_stats([a, b])
    assert m.steps == 7 and m.prefix_hits == 3 and m.prefix_queries == 6
    assert m.prefix_hit_rate == 0.5
    assert m.trace_count("raw_rewards") == 5
    np.testing.assert_allclose(m.trace_mean("raw_rewards"), 3.0)
    np.testing.assert_allclose(m.trace_var("raw_rewards"), 2.0)
    # inputs untouched
    assert a.steps == 3 and len(a.raw_rewards) == 1


# ----------------------------------------------------------------------
# Cache-aware admission ordering
# ----------------------------------------------------------------------

def _drain(sched, rid, rng):
    while rid not in sched.responses:
        rng, k = jax.random.split(rng)
        sched.step(k)
    return rng


@pytest.mark.parametrize("cache_aware,first", [(True, "hit"),
                                               (False, "cold")])
def test_cache_aware_admission_prefers_radix_hits(triple, gcfg,
                                                  cache_aware, first):
    sched = GSIScheduler(_engine(triple, gcfg), capacity=1,
                         cache_aware=cache_aware)
    warm = sched.submit(_prompt(PRE_A, [33, 34, 4]), max_steps=1)
    rng = _drain(sched, warm, jax.random.PRNGKey(5))
    assert sched.engine.pager.num_cached > 0
    # cold (different preamble) submitted BEFORE the hit
    sched.submit(_prompt(PRE_B, [35, 36, 4]), request_id="cold",
                 max_steps=1)
    sched.submit(_prompt(PRE_A, [37, 38, 4]), request_id="hit",
                 max_steps=1)
    rng, k = jax.random.split(rng)
    done = sched.step(k)
    # budget 1: whichever request was admitted first also finished first
    assert [r.request_id for r in done] == [first]
    _drain(sched, "cold", rng)
    _drain(sched, "hit", rng)
    assert set(sched.responses) == {warm, "cold", "hit"}


def test_cache_aware_bypass_is_bounded(triple, gcfg):
    """An endless supply of fresher cache hits must not starve a cold
    head-of-queue request: after ``_bypass_limit`` consecutive bypassed
    admissions the head is forced through."""
    sched = GSIScheduler(_engine(triple, gcfg), capacity=1,
                         cache_aware=True)
    sched._bypass_limit = 2                  # keep the test short
    warm = sched.submit(_prompt(PRE_A, [33, 34, 4]), max_steps=1)
    rng = _drain(sched, warm, jax.random.PRNGKey(9))
    sched.submit(_prompt(PRE_B, [35, 36, 4]), request_id="cold",
                 max_steps=1)
    for i in range(4):
        sched.submit(_prompt(PRE_A, [40 + i, 34, 4]),
                     request_id=f"hit{i}", max_steps=1)
    order = []
    while len(sched.responses) < 6:
        rng, k = jax.random.split(rng)
        order.extend(r.request_id for r in sched.step(k))
    # two hits bypass the cold head, then the bound forces it through
    assert order[:3] == ["hit0", "hit1", "cold"]


# ----------------------------------------------------------------------
# fresh_state: stale-counter fix
# ----------------------------------------------------------------------

def test_scheduler_fresh_state_resets_prefix_counters(triple, gcfg):
    sched = GSIScheduler(_engine(triple, gcfg), capacity=1)
    for i in range(2):
        sched.submit(_prompt(PRE_A, [33 + i, 34, 4]), max_steps=1)
    sched.run(jax.random.PRNGKey(1))
    st = sched.prefix_stats()
    assert st["queries"] == 2 and st["hits"] == 1
    sched.fresh_state()
    st = sched.prefix_stats()
    assert st["queries"] == 0 and st["hits"] == 0
    assert st["pages_cached"] == 0 and st["prefill_tokens"] == 0
    assert sched.engine_steps == 0 and not sched.responses
    # the scheduler is immediately servable again, from a cold cache
    rid = sched.submit(_prompt(PRE_A, [39, 40, 4]), max_steps=1)
    out = sched.run(jax.random.PRNGKey(2))
    assert rid in out
    assert sched.prefix_stats()["queries"] == 1
    assert sched.prefix_stats()["hits"] == 0     # cache really was cold


def test_router_fresh_state_resets_fleet(triple, gcfg):
    router = ReplicaRouter([_engine(triple, gcfg), _engine(triple, gcfg)],
                           capacity=1, policy="affinity", skew=None)
    for i in range(2):
        router.submit(_prompt(PRE_A, [33 + i, 34, 4]), max_steps=1)
    router.run(jax.random.PRNGKey(1))
    assert router.prefix_stats()["queries"] == 2
    router.fresh_state()
    st = router.prefix_stats()
    assert st["queries"] == 0 and st["hits"] == 0
    assert router.engine_steps == 0 and not router.responses
    assert all(v == 0 for v in router.routing.values())
    rid = router.submit(_prompt(PRE_A, [39, 40, 4]), max_steps=1)
    assert rid in router.run(jax.random.PRNGKey(2))
