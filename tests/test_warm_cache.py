"""Warm-cache lifecycle: decode-time page publication, hot-cache
snapshot/restore and rendezvous cache migration.

Differential coverage:
  * Decode-time publication is behaviour-invisible: tokens are
    bit-identical with publication on/off across full / sliding-window /
    hybrid stacks, in both the sync and the pipelined scheduler loops.
  * Second-wave requests over a *generated* trajectory hit the radix
    beyond the prompt pages — the pages decode published are matchable.
  * Snapshot -> disk -> fresh-engine restore round-trips byte-identically
    (codes and scale rows for quantized pools), preserves greedy-seeded
    tokens and the hit rate, and keeps the page conservation ledger and
    ``scale_slots`` lockstep intact.
  * Restoring into a *busy* engine never resurrects pages the allocator
    handed to live slots: restored pages come exclusively off the free
    list and admission reservations stay honourable.
  * ``ReplicaRouter.add_replica`` pushes a remapped preamble group's hot
    pages to the new replica (rendezvous: every moved group lands there)
    and the destination reports a radix hit on its first admission.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.config import GSIConfig, ModelConfig
from repro.models import build_model
from repro.serving import (GSIScheduler, GSIServingEngine, ReplicaRouter,
                           load_snapshot)
from repro.serving.router import preamble_rendezvous

PAD = 0

# 2 full pages (ps=8) of shared preamble + distinct per-request tails
PRE = np.asarray([5 + (i % 24) for i in range(17)], np.int32)


def _prompt(tail, pre=PRE):
    return np.concatenate([pre, np.asarray(tail, np.int32)])


def _triple(draft):
    target = dataclasses.replace(draft, name=draft.name + "-t",
                                 num_layers=3)
    prm = dataclasses.replace(target, name=draft.name + "-p",
                              reward_head=True)
    params = (build_model(draft).init(jax.random.PRNGKey(0)),
              build_model(target).init(jax.random.PRNGKey(1)),
              build_model(prm).init(jax.random.PRNGKey(2)))
    return (draft, target, prm), params


def _stack_triple(pattern, window):
    base = ModelConfig(
        name=f"t-wc-{'-'.join(pattern)}-{window}", family="dense"
        if "recurrent" not in pattern else "hybrid",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
        vocab_size=64, head_dim=16, dtype="float32", param_dtype="float32",
        layer_pattern=pattern, window_size=window or 4096)
    return _triple(base)


@pytest.fixture(scope="module")
def gcfg():
    return GSIConfig(n=2, max_step_tokens=5, max_steps=3, beta=4.0,
                     min_step_reward=-1.0)


@pytest.fixture(scope="module")
def full_triple():
    return _stack_triple(("full",), 0)


def _check_ledger(pool):
    """Page conservation + scale-slot lockstep (serving/pages.py)."""
    free = set(pool.free)
    referenced = set(pool.refcount)
    cached = set(pool.cached)
    assert len(free) == len(pool.free)
    assert free | referenced | cached == set(range(pool.num_pages))
    assert not free & referenced and not free & cached
    assert not referenced & cached
    assert pool.num_free >= pool.num_claimed
    assert cached == pool.retained - referenced
    if pool.index is not None:
        assert set(pool.index.nodes) == pool.retained
    if pool.quantized:
        assert pool.scale_slots == referenced | cached
    else:
        assert not pool.scale_slots


def _sched_run(engine, prompts, *, capacity=2, sync=True, seed=7,
               max_steps=None):
    sched = GSIScheduler(engine, capacity=capacity, sync=sync)
    ids = [sched.submit(p, max_steps=max_steps) for p in prompts]
    out = sched.run(jax.random.PRNGKey(seed))
    return {r: out[r].tokens.tolist() for r in ids}, sched


# ----------------------------------------------------------------------
# Decode-time publication: behaviour-invisible, trajectory matchable
# ----------------------------------------------------------------------

@pytest.mark.parametrize("pattern,window", [
    (("full",), 0),
    (("full", "local"), 12),
    (("recurrent", "full"), 0),
])
@pytest.mark.parametrize("sync", [True, False])
def test_decode_publication_token_identity(gcfg, pattern, window, sync):
    """Publication on/off must be bit-identical: it changes neither rng
    consumption nor admission timing (pages move free<->cached, the
    evictable total is unchanged), and published pages hold exactly the
    KV that decoding produced."""
    cfgs, params = _stack_triple(pattern, window)
    prompts = [_prompt([33, 34, 4]), _prompt([35, 36, 4]),
               _prompt([37, 38, 4])]
    runs, scheds = {}, {}
    for name, pub in [("on", True), ("off", False)]:
        eng = GSIServingEngine(*cfgs, *params, gcfg, max_seq=96,
                               paged=True, page_size=8,
                               decode_publish=pub)
        runs[name], scheds[name] = _sched_run(eng, prompts, sync=sync)
        _check_ledger(eng.pager)
    assert runs["on"] == runs["off"]
    on = scheds["on"].prefix_stats()
    if scheds["on"].engine.prefix_cache:      # hybrid auto-disables
        assert on["pages_published_decode"] >= 1
    assert scheds["off"].prefix_stats()["pages_published_decode"] == 0


def test_second_wave_hits_generated_trajectory(full_triple, gcfg):
    """A request whose prompt extends a *generated* trajectory must
    splice the decode-published pages — more tokens than the original
    prompt's pages alone could cover."""
    cfgs, params = full_triple
    eng = GSIServingEngine(*cfgs, *params, gcfg, max_seq=96, paged=True,
                           page_size=8)
    sched = GSIScheduler(eng, capacity=1)
    first = _prompt([33, 34, 4])              # 20 tokens -> 2 full pages
    a = sched.submit(first, max_steps=2)
    out = sched.run(jax.random.PRNGKey(3))
    st0 = sched.prefix_stats()
    assert st0["pages_published_decode"] >= 1
    traj = np.concatenate([first, out[a].tokens.astype(np.int32)])
    _, matched = eng.match_prefix(traj)
    assert matched > 16                       # beyond the prompt's pages
    expected = min(matched, (traj.size - 1) // 8 * 8)
    b = sched.submit(traj, max_steps=2)
    out2 = sched.run(jax.random.PRNGKey(4))
    assert b in out2
    st1 = sched.prefix_stats()
    assert st1["hits"] == st0["hits"] + 1
    assert st1["hit_tokens"] - st0["hit_tokens"] == expected
    _check_ledger(eng.pager)


# ----------------------------------------------------------------------
# Snapshot / restore round-trip
# ----------------------------------------------------------------------

def _record_paths(snap):
    """Root-to-node token path (tuple of chunks) for every record."""
    chunks = [tuple(int(t) for t in c) for c in snap["chunks"]]
    parents = np.asarray(snap["parents"], np.int64)
    paths = []
    for i in range(len(chunks)):
        path, j = [], i
        while j != -1:
            path.append(chunks[j])
            j = int(parents[j])
        paths.append(tuple(reversed(path)))
    return paths


def _canon(snap):
    """Snapshot as {token path: {leaf: row}} — page-id independent."""
    out = {}
    for i, path in enumerate(_record_paths(snap)):
        row = {}
        for key, arr in snap["leaves"].items():
            axis = 1 if "blocks" in key.split(".") else 0
            row[key] = np.take(np.asarray(arr), i, axis=axis)
        out[path] = row
    return out


@pytest.mark.parametrize("kv_dtype", [None, "int8"])
def test_snapshot_restore_roundtrip(full_triple, gcfg, tmp_path,
                                    kv_dtype):
    """Disk round-trip into a fresh engine: byte-identical payloads
    (codes + scales for int8), identical same-seed tokens, restored
    hit rate at least the cold run's, ledger intact."""
    cfgs, params = full_triple
    mk = dict(max_seq=96, paged=True, page_size=8, kv_dtype=kv_dtype)
    eng = GSIServingEngine(*cfgs, *params, gcfg, **mk)
    prompts = [_prompt([33, 34, 4]), _prompt([35, 36, 4])]
    # capacity=1 serialises admission: the second request hits the
    # preamble pages the first one published -> cold hit rate 1/2
    cold, sched = _sched_run(eng, prompts, capacity=1, seed=3)
    st_cold = sched.prefix_stats()
    assert st_cold["hits"] == 1
    path = tmp_path / "cache.npz"
    snap = eng.save_cache(sched.state, path)
    assert snap["pages"].size >= 2
    if kv_dtype == "int8":
        codes = [k for k in snap["leaves"] if k.split(".")[-1] == "kp"]
        scales = [k for k in snap["leaves"] if k.split(".")[-1] == "ks"]
        assert codes and scales
        assert all(snap["leaves"][k].dtype == np.int8 for k in codes)
    loaded = load_snapshot(path)
    assert loaded["page_size"] == 8
    assert (loaded["kv_dtype"] or None) == kv_dtype

    eng2 = GSIServingEngine(*cfgs, *params, gcfg, **mk)
    sched2 = GSIScheduler(eng2, capacity=1)
    sched2.state = eng2.load_cache(sched2.state, str(path))
    _check_ledger(eng2.pager)
    assert eng2.pager.num_cached == snap["pages"].size
    # byte-identity, page-id independent: every restored node's payload
    # rows (codes AND scales) equal the snapshotted ones
    snap2 = eng2.save_cache(sched2.state)
    a, b = _canon(snap), _canon(snap2)
    assert a.keys() == b.keys()
    for p in a:
        assert a[p].keys() == b[p].keys()
        for key in a[p]:
            assert a[p][key].dtype == b[p][key].dtype
            np.testing.assert_array_equal(a[p][key], b[p][key])
    # warm rerun: same seed -> identical tokens; every admission hits
    ids = [sched2.submit(p) for p in prompts]
    out = sched2.run(jax.random.PRNGKey(3))
    warm = {r: out[r].tokens.tolist() for r in ids}
    assert list(warm.values()) == list(cold.values())
    st_warm = sched2.prefix_stats()
    assert st_warm["hits"] == 2
    assert st_warm["hit_rate"] >= st_cold["hit_rate"]
    _check_ledger(eng2.pager)


def test_restore_into_busy_engine_never_resurrects_pages(full_triple,
                                                         gcfg, tmp_path):
    """Restoring while slots hold referenced pages and admission holds
    free-page reservations must draw exclusively from the *unreserved*
    free list: live assignments, refcounts and claims are untouched."""
    cfgs, params = full_triple
    mk = dict(max_seq=96, paged=True, page_size=8)
    donor = GSIServingEngine(*cfgs, *params, gcfg, **mk)
    pre_a = np.asarray([21 + (i % 10) for i in range(17)], np.int32)
    _, dsched = _sched_run(donor, [_prompt([33, 34, 4], pre_a),
                                   _prompt([35, 36, 4], pre_a)],
                           capacity=1, seed=3)
    path = tmp_path / "donor.npz"
    donor.save_cache(dsched.state, path)

    eng = GSIServingEngine(*cfgs, *params, gcfg, **mk)
    sched = GSIScheduler(eng, capacity=2)
    b = sched.submit(_prompt([41, 42, 4]), max_steps=3)
    rng = jax.random.PRNGKey(9)
    for _ in range(2):                        # request is now mid-decode
        rng, k = jax.random.split(rng)
        sched.step(k)
    pool = eng.pager
    assert pool.num_referenced > 0
    ref_before = dict(pool.refcount)
    assigned_before = {s: list(p) for s, p in pool.assigned.items()}
    cached_before = set(pool.cached)

    sched.state = eng.load_cache(sched.state, str(path))
    _check_ledger(pool)
    # live pages untouched; everything restored came off the free list
    assert dict(pool.refcount) == ref_before
    assert {s: list(p) for s, p in pool.assigned.items()} \
        == assigned_before
    restored = pool.cached - cached_before
    assert restored and not restored & set(ref_before)
    assert pool.num_free >= pool.num_claimed
    # the in-flight request still finishes cleanly on the spliced state
    while b not in sched.responses:
        rng, k = jax.random.split(rng)
        sched.step(k)
    _check_ledger(pool)


# ----------------------------------------------------------------------
# Rendezvous cache migration
# ----------------------------------------------------------------------

def test_add_replica_migrates_remapped_groups(full_triple, gcfg):
    """Scale-out 1 -> 2 under rendezvous hashing: groups that remap to
    the new replica arrive there as spliced pages (radix hit on first
    admission), groups that keep their placement stay put."""
    cfgs, params = full_triple
    mk = dict(max_seq=96, paged=True, page_size=8)
    # probed rendezvous placements over 2 replicas for these chunks:
    # base 2 -> replica 1 (moves), base 3 -> replica 0 (stays)
    pre_move = np.asarray([2 + (i % 10) for i in range(17)], np.int32)
    pre_stay = np.asarray([3 + (i % 10) for i in range(17)], np.int32)
    assert preamble_rendezvous(pre_move[:8], 2) == 1
    assert preamble_rendezvous(pre_stay[:8], 2) == 0

    eng0 = GSIServingEngine(*cfgs, *params, gcfg, **mk)
    router = ReplicaRouter([eng0], capacity=2, policy="affinity",
                           hash_tier="rendezvous", skew=None,
                           threaded=False)
    for pre in (pre_move, pre_stay):
        for tail in ([33, 34, 4], [35, 36, 4]):
            router.submit(_prompt(tail, pre))
    router.run(jax.random.PRNGKey(5))
    assert eng0.pager.num_cached >= 4         # both groups' preambles

    eng1 = GSIServingEngine(*cfgs, *params, gcfg, **mk)
    moved = router.add_replica(eng1)
    assert moved["groups_moved"] >= 1
    assert moved["pages_moved"] >= 2
    _check_ledger(eng0.pager)
    _check_ledger(eng1.pager)
    # moved group: pages live on the new replica, gone from the source
    assert eng1.match_prefix(_prompt([99], pre_move))[1] >= 16
    assert eng0.match_prefix(_prompt([99], pre_move))[1] == 0
    # stayed group: untouched on the source, absent from the new replica
    assert eng0.match_prefix(_prompt([99], pre_stay))[1] >= 16
    assert eng1.match_prefix(_prompt([99], pre_stay))[1] == 0

    # tier-1 affinity follows the pages: the next same-preamble request
    # routes to the destination and hits the radix on first admission
    rid = router.submit(_prompt([41, 42, 4], pre_move))
    assert router.replica_of(rid) == 1
    out = router.run(jax.random.PRNGKey(6))
    assert rid in out
    st = router.replicas[1].scheduler.prefix_stats()
    assert st["hits"] >= 1 and st["hit_tokens"] >= 16
    _check_ledger(eng1.pager)


def test_add_replica_rejects_mismatched_engine(full_triple, gcfg):
    """Fleet homogeneity is enforced on scale-out too: shared engine
    objects and kv_dtype mismatches are rejected outright."""
    cfgs, params = full_triple
    eng0 = GSIServingEngine(*cfgs, *params, gcfg, max_seq=96, paged=True,
                            page_size=8)
    router = ReplicaRouter([eng0], capacity=1, threaded=False)
    with pytest.raises(ValueError, match="share engine"):
        router.add_replica(eng0)
    other = GSIServingEngine(*cfgs, *params, gcfg, max_seq=96, paged=True,
                             page_size=8, kv_dtype="int8")
    with pytest.raises(ValueError, match="kv_dtype"):
        router.add_replica(other)
    assert router.num_replicas == 1           # failed adds leave no stub
