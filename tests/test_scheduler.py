"""Continuous-batching scheduler + slot-pool tests (tiny models)."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.config import GSIConfig
from repro.models import build_model
from repro.serving import (GSIScheduler, GSIServingEngine, SlotPool,
                           pack_prompts, reset_cache_rows)

PAD = 0


@pytest.fixture(scope="module")
def engine(tiny_triple):
    draft, target, prm = tiny_triple
    ps = build_model(draft).init(jax.random.PRNGKey(0))
    pb = build_model(target).init(jax.random.PRNGKey(1))
    pp = build_model(prm).init(jax.random.PRNGKey(2))
    g = GSIConfig(n=2, max_step_tokens=5, max_steps=3, beta=4.0,
                  min_step_reward=-1.0)
    return GSIServingEngine(draft, target, prm, ps, pb, pp, g, max_seq=48)


# ----------------------------------------------------------------------
# SlotPool ledger
# ----------------------------------------------------------------------

def test_slot_pool_claim_release():
    pool = SlotPool(3)
    assert pool.free_slots() == [0, 1, 2]
    pool.claim(1, "a")
    assert pool.num_live == 1 and pool.slot_of("a") == 1
    with pytest.raises(ValueError):
        pool.claim(1, "b")
    assert pool.release(1) == "a"
    with pytest.raises(ValueError):
        pool.release(1)
    assert pool.num_free == 3


def test_pack_prompts_layout():
    packed = pack_prompts({0: np.array([5, 6]), 2: np.array([7])}, 3, 4)
    np.testing.assert_array_equal(packed[0], [5, 6, PAD, PAD])
    np.testing.assert_array_equal(packed[1], [PAD] * 4)
    np.testing.assert_array_equal(packed[2], [7, PAD, PAD, PAD])
    with pytest.raises(ValueError):
        pack_prompts({0: np.arange(1, 6)}, 3, 4)


# ----------------------------------------------------------------------
# Cache helpers
# ----------------------------------------------------------------------

def test_reset_cache_rows_zeroes_only_masked(tiny_dense):
    m = build_model(tiny_dense)
    cache = jax.tree.map(lambda a: a + 1.0, m.init_cache(3, 8))
    out = reset_cache_rows(cache, np.array([True, False, True]))
    for path, leaf in jax.tree_util.tree_flatten_with_path(out)[0]:
        d = 1 if any(getattr(p, "key", None) == "blocks" for p in path) \
            else 0
        moved = np.moveaxis(np.asarray(leaf), d, 0)
        assert (moved[0] == 0).all() and (moved[2] == 0).all()
        assert (moved[1] == 1).all()


# ----------------------------------------------------------------------
# Slot free / re-admit round-trip
# ----------------------------------------------------------------------

def test_slot_readmit_preserves_other_rows(engine):
    """Freeing slot 0 and admitting a new prompt must leave slot 1's
    *committed* cache region bit-identical (the admission commit may
    idempotently pre-write the pending token's KV at ``pos``), set slot 0
    to the prefill invariant (cache holds prompt[:-1], pending =
    prompt[-1]), and leave slot 1's subsequent decode unchanged."""
    state = engine.fresh_state(2)
    state = engine.admit(state, np.array([True, True]),
                         np.array([[5, 6, 7, PAD], [8, 9, 3, 4]], np.int32))
    state, _ = engine.step_decode(state, jax.random.PRNGKey(0))
    undisturbed = dict(state)
    pos1 = int(state["pos"][1])
    before = jax.tree_util.tree_flatten_with_path(state["caches"])[0]

    state = engine.admit(state, np.array([True, False]),
                         np.array([[9, 9, PAD, PAD], [PAD] * 4], np.int32))
    after = jax.tree_util.tree_flatten_with_path(state["caches"])[0]
    for (path, b), (_, a) in zip(before, after):
        stacked = any(getattr(p, "key", None) == "blocks" for p in path)
        d = 1 if stacked else 0
        row_b = np.moveaxis(np.asarray(b), d, 0)[1]
        row_a = np.moveaxis(np.asarray(a), d, 0)[1]
        if row_b.ndim >= 2:                   # attention KV: slice seq axis
            seq_ax = 1 if stacked else 0
            sl = [slice(None)] * row_b.ndim
            sl[seq_ax] = slice(0, pos1)
            row_b, row_a = row_b[tuple(sl)], row_a[tuple(sl)]
        np.testing.assert_array_equal(row_b, row_a)
    assert int(state["pos"][0]) == 1          # prompt[:-1] committed
    assert int(state["pending"][0]) == 9      # pending = prompt[-1]
    assert not bool(state["done"][0])

    # behavioural round-trip: slot 1's next step is identical whether or
    # not slot 0 was freed and re-admitted underneath it
    _, res_ref = engine.step_decode(undisturbed, jax.random.PRNGKey(11))
    _, res_new = engine.step_decode(state, jax.random.PRNGKey(11))
    np.testing.assert_array_equal(res_ref.chosen[1], res_new.chosen[1])


def test_fresh_state_slots_are_inert(engine):
    """Decoding an all-free pool commits nothing and finishes nothing."""
    state = engine.fresh_state(2)
    pos0 = np.asarray(state["pos"]).copy()
    state, res = engine.step_decode(state, jax.random.PRNGKey(0))
    assert res.done_prev.all()
    assert (res.chosen == PAD).all()
    np.testing.assert_array_equal(np.asarray(state["pos"]), pos0)


# ----------------------------------------------------------------------
# Scheduler behaviour
# ----------------------------------------------------------------------

def test_freed_slot_readmitted_next_step(engine):
    """A freed slot must pick up the next queued prompt on the very next
    scheduler step (the continuous-batching acceptance criterion)."""
    sched = GSIScheduler(engine, capacity=1)
    first = sched.submit([5, 6, 4], max_steps=1)
    second = sched.submit([7, 3, 4], max_steps=1)
    rng = jax.random.PRNGKey(0)
    rng, k = jax.random.split(rng)
    done = sched.step(k)
    assert [r.request_id for r in done] == [first]
    assert sched.pool.num_free == 1 and len(sched.queue) == 1
    rng, k = jax.random.split(rng)
    done = sched.step(k)                      # re-admit + decode, one step
    assert [r.request_id for r in done] == [second]
    assert sched.engine_steps == 2


def test_scheduler_matches_fixed_run_when_capacity_covers(engine):
    """With capacity >= #requests the scheduler reproduces engine.run()
    trajectories exactly (same rng stream, bit-identical admission)."""
    prompts = np.array([[5, 6, 4], [7, 3, 4]], np.int32)
    responses, _ = engine.run(prompts, jax.random.PRNGKey(3))
    sched = GSIScheduler(engine, capacity=2)
    ids = [sched.submit(p) for p in prompts]
    out = sched.run(jax.random.PRNGKey(3))
    for b, rid in enumerate(ids):
        got = [s.tolist() for s in out[rid].steps]
        want = [s.tolist() for s in responses[b]]
        assert got == want


def test_out_of_order_completion_assembly(engine):
    """Responses are keyed by request id even when later submissions
    finish first and slots are recycled through multiple requests."""
    sched = GSIScheduler(engine, capacity=2)
    budgets = {"long": 3, "s1": 1, "s2": 1, "s3": 1}
    for rid, b in budgets.items():
        sched.submit([5, 6, 4], request_id=rid, max_steps=b)
    out = sched.run(jax.random.PRNGKey(7))
    assert set(out) == set(budgets)
    for rid, b in budgets.items():
        assert out[rid].engine_steps == b, rid
        assert out[rid].finish_reason in ("max_steps", "eos", "low_reward")
    # short requests time-share one slot while "long" holds the other:
    # total engine steps < sum of per-request steps (capacity reclaimed)
    assert sched.engine_steps < sum(budgets.values())
    assert out["s3"].finished_at >= out["s1"].finished_at
    assert sched.stats.requests_finished == 4


def test_admission_control_rejects_oversized(engine):
    sched = GSIScheduler(engine, capacity=1)
    with pytest.raises(ValueError):
        sched.submit(np.arange(1, 60), max_steps=3)   # needs > max_seq
    with pytest.raises(ValueError):
        sched.submit([], max_steps=1)


def test_gang_mode_admits_only_into_empty_pool(engine):
    sched = GSIScheduler(engine, capacity=2, continuous=False)
    for i, b in enumerate([2, 1, 1]):
        sched.submit([5, 6, 4], max_steps=b, request_id=f"r{i}")
    rng = jax.random.PRNGKey(0)
    rng, k = jax.random.split(rng)
    done = sched.step(k)                      # r0,r1 admitted; r1 finishes
    assert [r.request_id for r in done] == ["r1"]
    assert len(sched.queue) == 1              # r2 must wait for the gang
    rng, k = jax.random.split(rng)
    done = sched.step(k)                      # r0 finishes; r2 NOT admitted
    assert [r.request_id for r in done] == ["r0"]
    rng, k = jax.random.split(rng)
    done = sched.step(k)                      # pool empty -> r2 admitted
    assert [r.request_id for r in done] == ["r2"]


def test_arrival_order_beats_submit_order(engine):
    """An early arrival submitted late must not be head-of-line blocked
    behind a not-yet-arrived request submitted before it."""
    sched = GSIScheduler(engine, capacity=1)
    sched.submit([5, 6, 4], request_id="late", max_steps=1,
                 arrival_time=30.0)
    sched.submit([7, 3, 4], request_id="early", max_steps=1,
                 arrival_time=0.0)
    assert sched.queue[0].id == "early"
    done = sched.step(jax.random.PRNGKey(0))
    assert [r.request_id for r in done] == ["early"]
    assert sched.pool.num_free == 1 and sched.queue[0].id == "late"


def test_run_ignores_all_pad_padding_rows(engine):
    """engine.run on a partial batch padded with all-PAD rows must treat
    the padding as already done (no phantom decoding)."""
    prompts = np.array([[5, 6, 4], [0, 0, 0]], np.int32)
    responses, stats = engine.run(prompts, jax.random.PRNGKey(3))
    assert responses[1] == []
    assert stats.decisions <= stats.steps   # only the one live request


def test_repeat_cache_unstacked_layout(tiny_dense):
    """repeat_cache expands dim 0 for unscanned (rem) cache entries."""
    from repro.serving import repeat_cache
    cfg = dataclasses.replace(tiny_dense, scan_layers=False)
    m = build_model(cfg)
    cache = m.init_cache(2, 8)
    rep = repeat_cache(cache, 3)
    leaves = jax.tree.leaves(rep)
    assert all(leaf.shape[0] == 6 for leaf in leaves)
