"""GSI serving engine integration tests (tiny models, all modes)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import GSIConfig
from repro.data import SyntheticReasoningTask
from repro.models import build_model
from repro.serving import GSIServingEngine
from repro.serving.engine import (fold_candidates, repeat_cache,
                                  take_candidates)


@pytest.fixture(scope="module")
def engine_setup(tiny_triple):
    draft, target, prm = tiny_triple
    ps = build_model(draft).init(jax.random.PRNGKey(0))
    pb = build_model(target).init(jax.random.PRNGKey(1))
    pp = build_model(prm).init(jax.random.PRNGKey(2))
    return draft, target, prm, ps, pb, pp


def test_repeat_cache_layout(tiny_dense):
    m = build_model(tiny_dense)
    cache = m.init_cache(2, 8)
    rep = repeat_cache(cache, 3)
    k0 = jax.tree.leaves(cache)[0]
    k1 = jax.tree.leaves(rep)[0]
    assert k1.shape[k0.ndim - 4] == 3 * k0.shape[k0.ndim - 4] or \
        k1.shape[0] == 3 * k0.shape[0] or k1.shape[1] == 3 * k0.shape[1]


def test_take_candidates():
    cands = jnp.arange(2 * 3 * 4).reshape(2, 3, 4)
    idx = jnp.array([2, 0])
    out = take_candidates(cands, idx)
    np.testing.assert_array_equal(out[0], cands[0, 2])
    np.testing.assert_array_equal(out[1], cands[1, 0])


@pytest.mark.parametrize("mode", ["gsi", "rsd", "sbon_s", "sbon_b",
                                  "gsi_norej"])
def test_engine_modes_run(engine_setup, mode):
    draft, target, prm, ps, pb, pp = engine_setup
    g = GSIConfig(n=2, max_step_tokens=5, max_steps=3, beta=4.0,
                  min_step_reward=-1.0)
    eng = GSIServingEngine(draft, target, prm, ps, pb, pp, g, mode=mode,
                           max_seq=48)
    prompts = np.array([[5, 6, 4], [7, 3, 4]], np.int32)
    responses, stats = eng.run(prompts, jax.random.PRNGKey(3))
    assert stats.steps >= 1
    assert len(responses) == 2
    if mode in ("sbon_s", "gsi_norej"):
        assert stats.accept_rate == 1.0


def test_engine_commit_matches_prefill(engine_setup):
    """Engine state after prompt ingestion == direct prefill."""
    draft, target, prm, ps, pb, pp = engine_setup
    g = GSIConfig(n=2, max_step_tokens=4, max_steps=2)
    eng = GSIServingEngine(draft, target, prm, ps, pb, pp, g, max_seq=32)
    prompts = np.array([[5, 6, 7, 8]], np.int32)
    state = eng.init_state(prompts)
    m = build_model(draft)
    # engine invariant: cache holds prompt[:-1], pending = prompt[-1]
    _, cache_ref = m.prefill(ps, jnp.asarray(prompts[:, :-1]), max_seq=32)
    lg_ref, _ = m.decode_step(ps, cache_ref, jnp.asarray(prompts[:, -1:]),
                              jnp.full((1,), 3, jnp.int32))
    lg_eng, _ = m.decode_step(ps, state["caches"]["S"],
                              jnp.asarray(prompts[:, -1:]),
                              state["pos"])
    np.testing.assert_allclose(lg_eng, lg_ref, atol=2e-4, rtol=2e-4)
    assert int(state["pos"][0]) == 3
    assert int(state["pending"][0]) == 8


def test_admit_matches_init_state(engine_setup):
    """Prefill-into-slot (masked admission commit) == init_state prefill."""
    draft, target, prm, ps, pb, pp = engine_setup
    g = GSIConfig(n=2, max_step_tokens=4, max_steps=2)
    eng = GSIServingEngine(draft, target, prm, ps, pb, pp, g, max_seq=32)
    prompts = np.array([[5, 6, 7, 8], [9, 4, 3, 0]], np.int32)
    ref = eng.init_state(prompts)
    state = eng.admit(eng.fresh_state(2), np.array([True, True]), prompts)
    np.testing.assert_array_equal(np.asarray(state["pos"]),
                                  np.asarray(ref["pos"]))
    np.testing.assert_array_equal(np.asarray(state["pending"]),
                                  np.asarray(ref["pending"]))
    # identical next-step logits from both states
    m = build_model(draft)
    lg_a, _ = m.decode_step(ps, state["caches"]["S"],
                            state["pending"][:, None], state["pos"])
    lg_r, _ = m.decode_step(ps, ref["caches"]["S"],
                            ref["pending"][:, None], ref["pos"])
    np.testing.assert_allclose(np.asarray(lg_a), np.asarray(lg_r),
                               atol=1e-5, rtol=1e-5)


def test_trained_engine_beats_random(tmp_path):
    """Tiny end-to-end: trained triple gets >0 accuracy on easy problems."""
    from repro.launch.serve import evaluate, toy_triple, train_triple
    task = SyntheticReasoningTask(seed=0, min_terms=2, max_terms=2,
                                  max_value=4)
    d, t, p = toy_triple()
    ps, pb, pp = train_triple(task, d, t, p, steps_draft=60,
                              steps_target=140, batch=24, seq=32)
    g = GSIConfig(n=2, beta=8.0, threshold_u=0.4, max_step_tokens=6,
                  max_steps=3, min_step_reward=0.0)
    eng = GSIServingEngine(d, t, p, ps, pb, pp, g, max_seq=64)
    problems = [task.sample_problem() for _ in range(4)]
    res = evaluate(eng, task, problems, jax.random.PRNGKey(1))
    assert res["accuracy"] > 0.0


def test_shared_scoring_matches_baseline(engine_setup):
    """Beyond-paper shared-prefix scoring == baseline n-copy scoring."""
    draft, target, prm, ps, pb, pp = engine_setup
    g = GSIConfig(n=3, max_step_tokens=5, max_steps=2, beta=4.0,
                  min_step_reward=-1.0)
    e0 = GSIServingEngine(draft, target, prm, ps, pb, pp, g, max_seq=48)
    e1 = GSIServingEngine(draft, target, prm, ps, pb, pp, g, max_seq=48,
                          shared_scoring=True)
    prompts = np.array([[5, 6, 4], [7, 3, 4]], np.int32)
    s0 = e0.init_state(prompts)
    s1 = e1.init_state(prompts)
    d0 = e0._jit_draft_phase(s0, jax.random.PRNGKey(9))
    d1 = e1._jit_draft_phase(s1, jax.random.PRNGKey(9))
    np.testing.assert_array_equal(np.asarray(d0["cands"]),
                                  np.asarray(d1["cands"]))
    np.testing.assert_allclose(np.asarray(d0["logp_B"]),
                               np.asarray(d1["logp_B"]), atol=2e-3)
    np.testing.assert_allclose(np.asarray(d0["rewards"]),
                               np.asarray(d1["rewards"]), atol=2e-3)
