"""Core GSI math: tilting identity, selection, theorem validation, RSD."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ToyEnv, gsi_select, rsd_select, soft_bon_select,
                        theory, tilted_policy, tilted_rewards)
from repro.core.tilting import log_partition


def test_tilting_identity():
    """pi_S tilted by r~  ==  pi_B tilted by r (the §4 rewrite)."""
    env = ToyEnv(m=10, seed=1)
    beta = 2.0
    logp_b = jnp.log(env.pi_B)
    logp_s = jnp.log(env.pi_S)
    r_t = tilted_rewards(env.r, logp_b, logp_s, beta)
    lhs = jax.nn.softmax(jnp.log(env.pi_S) + beta * r_t)
    rhs = tilted_policy(env.pi_B, env.r, beta)
    np.testing.assert_allclose(lhs, rhs, atol=1e-6)


def test_log_partition_monotone_in_beta():
    env = ToyEnv(m=8, seed=2)
    zs = [float(log_partition(env.pi_B, env.r, b)) for b in (0.5, 1, 2, 4)]
    assert all(b > a for a, b in zip(zs, zs[1:]))
    assert float(log_partition(env.pi_B, env.r, 1e-9)) == pytest.approx(
        0.0, abs=1e-6)


def test_gsi_select_acceptance_threshold():
    rng = jax.random.PRNGKey(0)
    rewards = jnp.array([[0.9, 0.1], [0.05, 0.02]])
    logp = jnp.zeros((2, 2))
    dec = gsi_select(rng, rewards, logp, logp, beta=50.0, threshold_u=0.5)
    assert bool(dec.accept[0]) is True       # selects ~0.9 >= 0.5
    assert bool(dec.accept[1]) is False
    np.testing.assert_allclose(dec.tilted, rewards, atol=1e-6)


def test_theorem1_kl_bound_holds_on_toy():
    env = ToyEnv(m=12, seed=0)
    beta = 1.0
    tilted = env.tilted(beta)
    chi2 = float(env.chi2)
    r_max = float(env.r.max())
    prev_kl = None
    for n in [1, 4, 16]:
        trials = 120_000
        tr = env.run_gsi(jax.random.PRNGKey(n), n=n, beta=beta, u=0.5,
                         trials=trials)
        emp = env.histogram(tr.outcomes_tilde)
        kl = float(theory.kl_mc_estimate(tilted, emp * trials))
        bound = float(theory.theorem1_kl_bound(n, chi2, beta, r_max))
        assert kl <= bound + 1e-3, (n, kl, bound)
        if prev_kl is not None:
            assert kl <= prev_kl + 5e-3   # improves with n
        prev_kl = kl


def test_theorem1_n_bound_inverts_kl_bound():
    chi2, beta, r_max, eps = 2.0, 1.0, 1.0, 0.1
    n = float(theory.theorem1_n_bound(chi2, beta, r_max, eps))
    # at that n the KL bound equals eps
    kl = float(theory.theorem1_kl_bound(n, chi2, beta, r_max))
    assert kl == pytest.approx(eps, rel=1e-4)
    # the paper's worked example: chi2=2, beta=1, eps=0.1 -> n ~ 201
    assert 195 <= n <= 210


def test_theorem2_gap_bound_holds_on_toy():
    env = ToyEnv(m=12, seed=3)
    beta = 1.0
    tilted = env.tilted(beta)
    for n in [4, 16]:
        tr = env.run_gsi(jax.random.PRNGKey(n), n=n, beta=beta, u=0.5,
                         trials=120_000)
        emp = env.histogram(tr.outcomes)
        gap = float(env.expected_golden(tilted)
                    - jnp.sum(emp * env.r_star))
        bound = float(theory.theorem2_gap_bound(
            n, float(tr.accept.mean()), float(env.chi2),
            float(env.cv(beta)), beta, float(env.r.max()), 1.0))
        assert gap <= bound + 5e-3


def test_rsd_uses_raw_rewards():
    rng = jax.random.PRNGKey(0)
    rewards = jnp.array([[0.8, 0.2]])
    dec = rsd_select(rng, rewards, beta=50.0, threshold=0.7)
    assert bool(dec.accept[0])
    dec2 = rsd_select(rng, rewards * 0.5, beta=50.0, threshold=0.7)
    assert not bool(dec2.accept[0])


def test_soft_bon_limits():
    rng = jax.random.PRNGKey(0)
    r = jnp.array([[0.1, 0.9, 0.5]])
    # beta -> inf: argmax
    idx = soft_bon_select(rng, jnp.repeat(r, 64, 0), beta=1e4)
    assert (np.asarray(idx) == 1).all()
    # beta = 0: ~uniform
    idx0 = soft_bon_select(rng, jnp.repeat(r, 3000, 0), beta=0.0)
    counts = np.bincount(np.asarray(idx0), minlength=3) / 3000
    assert (np.abs(counts - 1 / 3) < 0.05).all()
