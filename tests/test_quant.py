"""Quantized serving tests: int8/fp8 KV pages + int8 draft weights.

Layers of coverage:
  * kernels/quant.py helpers (dtype validation, pool dtypes, codes).
  * Quantized paged-attention kernel (interpret mode) is BIT-IDENTICAL
    to its per-cell oracle ``paged_attention_quant_cell_ref`` — the
    jitted per-cell formulation mirrors the kernel's accumulation order
    exactly (XLA CPU reductions are shape-dependent, so the fast batched
    oracle only matches to float tolerance).
  * The fast production oracle ``paged_attention_quant_ref`` matches the
    kernel to tight float tolerance, and ``ops`` dispatch routes to it.
  * K/V page write round-trip error is bounded by the symmetric-scale
    quantization step (amax / QMAX per page per kv-head).
  * Draft weight fake-quant: per-channel error bound, skip rules
    (embeddings / reward head / vectors stay fp), dtype preservation.
  * End-to-end: quantized engines run through the scheduler on full /
    local / hybrid stacks with bounded acceptance-rate and mean-reward
    drift vs the fp engine (statistical contract — quantization
    legitimately perturbs logits, token identity is NOT expected).
  * COW candidate branching copies the branch-point page's scales, and
    radix prefix reuse serves quantized pages, with the scale-slot
    ledger in lockstep through claim / publish / release / drain.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import GSIConfig, ModelConfig
from repro.kernels import ops, quant, ref
from repro.kernels.paged_attention import paged_attention_quant_pallas
from repro.models import build_model
from repro.serving import (GSIScheduler, GSIServingEngine, branch_cache,
                           paged_view, quantize_draft_params,
                           quantized_fraction)

PAD = 0


def _triple(draft):
    target = dataclasses.replace(draft, name=draft.name + "-t",
                                 num_layers=3)
    prm = dataclasses.replace(target, name=draft.name + "-p",
                              reward_head=True)
    params = (build_model(draft).init(jax.random.PRNGKey(0)),
              build_model(target).init(jax.random.PRNGKey(1)),
              build_model(prm).init(jax.random.PRNGKey(2)))
    return (draft, target, prm), params


@pytest.fixture(scope="module")
def gcfg():
    return GSIConfig(n=2, max_step_tokens=5, max_steps=3, beta=4.0,
                     min_step_reward=-1.0)


@pytest.fixture(scope="module")
def dense_triple(tiny_dense):
    return _triple(tiny_dense)


def _quant_pages(key, P, ps, KV, hd, dtype="int8"):
    """Random fp pages -> (codes, scales) under the per-page per-kv-head
    symmetric scheme the engine uses."""
    fp = jax.random.normal(key, (P, ps, KV, hd))
    sc = jnp.maximum(jnp.max(jnp.abs(fp), axis=(1, 3)),
                     quant.EPS) / quant.QMAX[dtype]
    codes = quant.quantize_codes(fp / sc[:, None, :, None],
                                 quant.pool_dtype(dtype, jnp.float32))
    return codes, sc


# ----------------------------------------------------------------------
# kernels/quant.py helpers
# ----------------------------------------------------------------------

def test_kv_dtype_validation():
    for kd in quant.KV_DTYPES:
        quant.validate_kv_dtype(kd)
    with pytest.raises(ValueError):
        quant.validate_kv_dtype("int4")
    assert quant.is_quantized("int8") and quant.is_quantized("fp8")
    assert not quant.is_quantized(None) and not quant.is_quantized("bf16")
    assert quant.pool_dtype(None, jnp.float32) == jnp.float32
    assert quant.pool_dtype("bf16", jnp.float32) == jnp.bfloat16
    assert quant.pool_dtype("int8", jnp.float32) == jnp.int8


def test_quantize_codes_int8_saturates_and_rounds():
    x = jnp.array([0.0, 0.4, 0.6, -1.5, 200.0, -200.0])
    codes = quant.quantize_codes(x, jnp.int8)
    assert codes.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(codes),
                                  [0, 0, 1, -2, 127, -127])


# ----------------------------------------------------------------------
# Quantized paged-attention kernel vs oracles
# ----------------------------------------------------------------------

@pytest.mark.parametrize("B,H,KV,hd,ps,nblk,window", [
    (2, 4, 2, 16, 8, 3, 0),
    (1, 2, 1, 8, 4, 4, 0),
    (2, 2, 2, 8, 4, 5, 6),       # sliding window over small pages
])
def test_quant_kernel_bitwise_matches_cell_oracle(B, H, KV, hd, ps, nblk,
                                                  window):
    """Interpret-mode Pallas == the jitted per-cell oracle, bit for bit.

    The cell oracle replays the kernel's per-(b, h) online-softmax
    accumulation order in plain jnp; jitting it is essential — eager
    execution and any batched formulation pick different XLA reduction
    orders and only match to ~1e-6.
    """
    P = B * nblk + 2
    ks = jax.random.split(jax.random.PRNGKey(B + hd + window), 4)
    q = jax.random.normal(ks[0], (B, 1, H, hd))
    kp, ksc = _quant_pages(ks[1], P, ps, KV, hd)
    vp, vsc = _quant_pages(ks[2], P, ps, KV, hd)
    pt = jax.random.randint(ks[3], (B, nblk), 0, P)
    pos = jnp.asarray(np.linspace(0, nblk * ps - 1, B).astype(np.int32))
    out = paged_attention_quant_pallas(q, kp, vp, ksc, vsc, pt, pos,
                                       window=window, interpret=True)
    cell = jax.jit(ref.paged_attention_quant_cell_ref,
                   static_argnames=("window", "scale"))
    want = cell(q, kp, vp, ksc, vsc, pt, pos, window=window)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


def test_quant_kernel_close_to_fast_oracle():
    """The fast batched production oracle agrees to float tolerance."""
    B, H, KV, hd, ps, nblk = 2, 4, 2, 16, 8, 4
    P = B * nblk + 2
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    q = jax.random.normal(ks[0], (B, 1, H, hd))
    kp, ksc = _quant_pages(ks[1], P, ps, KV, hd)
    vp, vsc = _quant_pages(ks[2], P, ps, KV, hd)
    pt = jax.random.randint(ks[3], (B, nblk), 0, P)
    pos = jnp.array([ps - 1, nblk * ps - 1])
    out = paged_attention_quant_pallas(q, kp, vp, ksc, vsc, pt, pos,
                                       interpret=True)
    want = ref.paged_attention_quant_ref(q, kp, vp, ksc, vsc, pt, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=3e-6, rtol=3e-6)


def test_quant_matches_fp_attention_within_quant_error():
    """Dequantized paged attention tracks the fp paged attention within
    the error the int8 rounding itself introduces."""
    B, H, KV, hd, ps, nblk = 2, 4, 2, 16, 8, 4
    P = B * nblk + 2
    ks = jax.random.split(jax.random.PRNGKey(9), 4)
    q = jax.random.normal(ks[0], (B, 1, H, hd))
    kfp = jax.random.normal(ks[1], (P, ps, KV, hd))
    vfp = jax.random.normal(ks[2], (P, ps, KV, hd))

    def q8(x):
        sc = jnp.maximum(jnp.max(jnp.abs(x), axis=(1, 3)),
                         quant.EPS) / 127.0
        return quant.quantize_codes(x / sc[:, None, :, None],
                                    jnp.int8), sc

    kp, ksc = q8(kfp)
    vp, vsc = q8(vfp)
    pt = jax.random.randint(ks[3], (B, nblk), 0, P)
    pos = jnp.array([11, nblk * ps - 1])
    got = ref.paged_attention_quant_ref(q, kp, vp, ksc, vsc, pt, pos)
    want = ref.paged_attention_ref(q, kfp, vfp, pt, pos)
    # attention output is a convex combination of V rows (+ softmax
    # weight shift from K error); a few quantization steps bound it
    step = float(jnp.max(jnp.maximum(ksc, vsc))) / 2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=6 * step)


def test_ops_dispatch_quant_interpret(monkeypatch):
    """REPRO_USE_PALLAS=interpret routes the quant op to the kernel."""
    monkeypatch.setenv("REPRO_USE_PALLAS", "interpret")
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 1, 2, 8))
    kp, ksc = _quant_pages(ks[1], 4, 4, 2, 8)
    vp, vsc = _quant_pages(ks[2], 4, 4, 2, 8)
    pt = jnp.array([[2, 0, 3]])
    pos = jnp.array([9])
    np.testing.assert_allclose(
        np.asarray(ops.paged_attention_quant(q, kp, vp, ksc, vsc, pt,
                                             pos)),
        np.asarray(ref.paged_attention_quant_ref(q, kp, vp, ksc, vsc,
                                                 pt, pos)),
        atol=3e-6, rtol=3e-6)


# ----------------------------------------------------------------------
# K/V page write round-trip
# ----------------------------------------------------------------------

@pytest.mark.parametrize("kv_dtype", ["int8", "fp8"])
def test_paged_write_roundtrip_error_bounded(dense_triple, gcfg,
                                             kv_dtype):
    """Prefill-committing a prompt into quantized pages and dequantizing
    through paged_view reproduces the fp engine's committed K/V within
    the accumulated quantization error.

    The per-token admit scan requantizes the whole page whenever the
    running amax grows (re-rounding under an unchanged scale is exact),
    so a row written early can be double-rounded up to once per later
    in-page write: worst-case error (ps/2) quantization steps, typical
    error well under one.
    """
    cfgs, params = dense_triple
    e0 = GSIServingEngine(*cfgs, *params, gcfg, max_seq=48, paged=True,
                          page_size=8)
    e1 = GSIServingEngine(*cfgs, *params, gcfg, max_seq=48, paged=True,
                          page_size=8, kv_dtype=kv_dtype)
    prompts = np.array([[5, 6, 7, 8, 9, 3, 4], [7, 3, 4, PAD, PAD, PAD,
                                                PAD]], np.int32)
    s0 = e0.init_state(prompts)
    s1 = e1.init_state(prompts)
    v0 = paged_view(s0["caches"]["S"], s0["pt"])
    v1 = paged_view(s1["caches"]["S"], s1["pt"])
    pos = np.asarray(s0["pos"])
    # half a quantization step at the slice amax: int8 codes are uniform
    # (amax/127); fp8 e4m3 has 3 mantissa bits, so its half-ulp near
    # amax is amax * 2**-4 (float precision is relative, not uniform)
    inv_step = 127.0 if kv_dtype == "int8" else 16.0
    d0 = jax.tree_util.tree_flatten_with_path(v0)[0]
    d1 = jax.tree_util.tree_flatten_with_path(v1)[0]
    assert [p for p, _ in d0] == [p for p, _ in d1]
    checked = 0
    for (path, a), (_, b) in zip(d0, d1):
        if not any(getattr(p, "key", None) in ("k", "v") for p in path):
            continue
        stacked = any(getattr(p, "key", None) == "blocks" for p in path)
        a, b = np.asarray(a, np.float32), np.asarray(b, np.float32)
        for r in range(prompts.shape[0]):
            ra = a[:, r] if stacked else a[r]
            rb = b[:, r] if stacked else b[r]
            seq_ax = 1 if stacked else 0
            sl = [slice(None)] * ra.ndim
            sl[seq_ax] = slice(0, int(pos[r]))
            ra, rb = ra[tuple(sl)], rb[tuple(sl)]
            # double-rounding allows up to ps/2 accumulated steps, and
            # the typical row stays within one
            step = np.abs(ra).max() / inv_step
            err = np.abs(ra - rb)
            assert err.max() <= (8 / 2) * step + 1e-6
            assert err.mean() <= step
            checked += 1
    assert checked > 0


# ----------------------------------------------------------------------
# Draft weight int8 fake-quant
# ----------------------------------------------------------------------

def test_quantize_draft_params_error_and_skips(tiny_dense):
    params = build_model(tiny_dense).init(jax.random.PRNGKey(0))
    qparams = quantize_draft_params(tiny_dense, params)
    # structure and dtypes preserved
    assert jax.tree_util.tree_structure(params) \
        == jax.tree_util.tree_structure(qparams)
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(qparams)):
        assert a.shape == b.shape and a.dtype == b.dtype
    # embeddings stay full precision
    np.testing.assert_array_equal(
        np.asarray(params["embed"]["embedding"]),
        np.asarray(qparams["embed"]["embedding"]))
    # matmul weights actually move, but within the per-channel step
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    qflat = {jax.tree_util.keystr(p): a for p, a in
             jax.tree_util.tree_flatten_with_path(qparams)[0]}
    moved = 0
    for path, a in flat:
        b = qflat[jax.tree_util.keystr(path)]
        a, b = np.asarray(a, np.float32), np.asarray(b, np.float32)
        if not np.array_equal(a, b):
            moved += 1
            # |w - deq(q(w))| <= sc/2 <= amax / (2*127) elementwise;
            # the global amax bounds every channel's step
            assert np.abs(a - b).max() <= np.abs(a).max() / 127.0
    assert moved > 0
    frac = quantized_fraction(tiny_dense, params)
    assert 0.0 < frac < 1.0


def test_quantize_draft_skips_reward_head():
    cfg = ModelConfig(
        name="t-q-prm", family="dense", num_layers=2, d_model=32,
        num_heads=2, num_kv_heads=2, d_ff=64, vocab_size=32, head_dim=16,
        dtype="float32", param_dtype="float32", reward_head=True)
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    qparams = quantize_draft_params(cfg, params)
    np.testing.assert_array_equal(
        np.asarray(params["reward_head"]["w"]),
        np.asarray(qparams["reward_head"]["w"]))


# ----------------------------------------------------------------------
# End-to-end: bounded drift across stacks (the acceptance criterion)
# ----------------------------------------------------------------------

def _drift_stats(cfgs, params, gcfg, *, kv_dtype, quantize_draft, rng):
    eng = GSIServingEngine(*cfgs, *params, gcfg, max_seq=64, paged=True,
                           page_size=8, kv_dtype=kv_dtype,
                           quantize_draft=quantize_draft)
    sched = GSIScheduler(eng, capacity=2, collect_stats=True)
    for toks in ([5, 6, 4], [7, 3, 4], [9, 8, 4], [11, 5, 4]):
        sched.submit(toks)
    out = sched.run(rng)
    assert len(out) == 4
    pool = eng.pager
    assert pool.num_assigned == 0
    assert pool.num_free + pool.num_cached == eng.num_pages
    if pool.quantized:
        assert pool.scale_slots == pool.cached   # drained: no refs left
    else:
        assert not pool.scale_slots
    return (sched.stats.accept_rate,
            sched.stats.trace_mean("raw_rewards"))


@pytest.mark.parametrize("pattern,family,window", [
    (("full",), "dense", 0),
    (("full", "local"), "dense", 12),
    (("recurrent", "full"), "hybrid", 0),
])
def test_quantized_engine_bounded_drift(gcfg, pattern, family, window):
    """int8 KV + int8 draft vs fp on the same workload and rng: the
    drift contract is statistical — acceptance rate and mean PRM reward
    stay close — NOT token identity (quantization perturbs logits).
    Tiny deterministic workload, so the tolerances here are the test's
    fixed-seed envelope, not the paper-scale 2pp/1% claim (that one is
    asserted by ``benchmarks/throughput.py --check`` on the trained
    triple)."""
    base = ModelConfig(
        name=f"t-q-{'-'.join(pattern)}", family=family, num_layers=2,
        d_model=64, num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=64,
        head_dim=16, dtype="float32", param_dtype="float32",
        layer_pattern=pattern, window_size=window or 4096)
    cfgs, params = _triple(base)
    rng = jax.random.PRNGKey(11)
    a_fp, r_fp = _drift_stats(cfgs, params, gcfg, kv_dtype=None,
                              quantize_draft=False, rng=rng)
    a_q, r_q = _drift_stats(cfgs, params, gcfg, kv_dtype="int8",
                            quantize_draft=True, rng=rng)
    assert abs(a_q - a_fp) <= 0.35, \
        f"acceptance drifted: {a_q:.3f} vs fp {a_fp:.3f}"
    assert abs(r_q - r_fp) <= 0.05 * max(abs(r_fp), 1e-3), \
        f"mean reward drifted: {r_q:.4f} vs fp {r_fp:.4f}"


def test_bf16_pages_run_and_report_half_bytes(dense_triple, gcfg):
    """bf16 mode: plain cast, no scales, half the fp32 page bytes."""
    cfgs, params = dense_triple
    eng = GSIServingEngine(*cfgs, *params, gcfg, max_seq=48, paged=True,
                           page_size=8, kv_dtype="bf16")
    sched = GSIScheduler(eng, capacity=2)
    sched.submit([5, 6, 4])
    out = sched.run(jax.random.PRNGKey(0))
    assert len(out) == 1
    assert not eng.pager.quantized and not eng.pager.scale_slots
    rep = eng.cache_memory_report(2)
    assert rep["scale_bytes_per_page"] == 0
    assert rep["fp_bytes_per_page"] == 2 * rep["bytes_per_page"]


def test_kv_dtype_requires_paged(dense_triple, gcfg):
    cfgs, params = dense_triple
    with pytest.raises(ValueError):
        GSIServingEngine(*cfgs, *params, gcfg, max_seq=48,
                         kv_dtype="int8")
    with pytest.raises(ValueError):
        GSIServingEngine(*cfgs, *params, gcfg, max_seq=48, paged=True,
                         kv_dtype="int3")


# ----------------------------------------------------------------------
# COW branching + radix reuse on quantized pages
# ----------------------------------------------------------------------

def test_branch_cache_copies_scales_with_partial_page(dense_triple,
                                                      gcfg):
    """COW branching must carry the branch-point page's *scales* to each
    branch's first scratch page — otherwise the copied codes would be
    dequantized with the scratch page's stale scale."""
    cfgs, params = dense_triple
    eng = GSIServingEngine(*cfgs, *params, gcfg, max_seq=48, paged=True,
                           page_size=8, kv_dtype="int8")
    prompts = np.array([[5, 6, 7, 8, 9, 3, 2, 4, 11, 12, 13, 4]],
                       np.int32)
    state = eng.init_state(prompts)       # pos = 11: page 1 is partial
    cache = state["caches"]["S"]
    scr = state["scratch"][:, :2]
    branched = branch_cache(cache, 2, state["pt"], state["pos"], scr,
                            eng.page_size)
    pt = np.asarray(state["pt"])
    blk0 = int(state["pos"][0]) // 8
    src = pt[0, blk0]

    def leaves(tree, keys):
        return [(p, a) for p, a in
                jax.tree_util.tree_flatten_with_path(tree)[0]
                if any(getattr(s, "key", None) in keys for s in p)]

    pool_leaves = leaves(cache, ("kp", "vp", "ks", "vs"))
    assert any(any(getattr(s, "key", None) in ("ks", "vs") for s in p)
               for p, _ in pool_leaves)
    bmap = {jax.tree_util.keystr(p): a for p, a in
            leaves(branched, ("kp", "vp", "ks", "vs"))}
    for path, a in pool_leaves:
        b = bmap[jax.tree_util.keystr(path)]
        a, b = np.asarray(a), np.asarray(b)
        for jbr in range(2):
            dst = int(np.asarray(scr)[0, jbr, 0])
            if any(getattr(s, "key", None) == "blocks" for s in path):
                np.testing.assert_array_equal(b[:, dst], a[:, src])
            else:
                np.testing.assert_array_equal(b[dst], a[src])


def test_radix_reuse_on_quantized_pages(dense_triple, gcfg):
    """Shared-preamble prompts on an int8 engine: the radix cache serves
    quantized pages (codes + scales) across requests, and the scale-slot
    ledger stays in lockstep through publish / share / drain."""
    cfgs, params = dense_triple
    eng = GSIServingEngine(*cfgs, *params, gcfg, max_seq=64, paged=True,
                           page_size=8, kv_dtype="int8")
    sched = GSIScheduler(eng, capacity=2, collect_stats=True)
    pre = [5, 6, 7, 8, 9, 3, 2, 11]       # one full shared page
    for i in range(4):
        sched.submit(pre + [4 + i, 4])
    out = sched.run(jax.random.PRNGKey(2))
    assert len(out) == 4
    pstat = sched.prefix_stats()
    assert pstat["hits"] > 0 and pstat["pages_reused"] > 0
    pool = eng.pager
    assert pool.num_free + pool.num_referenced + pool.num_cached \
        == eng.num_pages
    assert pool.scale_slots == set(pool.refcount) | pool.cached
    # cached pages (awaiting reuse) still hold their scales; a full
    # eviction releases scales with their pages
    assert pool.num_cached > 0
    pool.evict(eng.num_pages)
    assert pool.num_free == eng.num_pages and not pool.scale_slots


def _two_cached_pages(page_bytes=0, override=None):
    """A pool holding exactly two cached pages, page A strictly staler
    than page B (published earlier, never re-touched)."""
    from repro.serving.pages import PagePool
    from repro.serving.radix import RadixIndex
    ps = 4
    pool = PagePool(4, ps, index=RadixIndex(ps), page_bytes=page_bytes,
                    page_cost_override=dict(override or {}))
    pool.claim(0, 1)
    pool.ensure(0, 1)
    pa = pool.assigned[0][0]
    pool.publish([1] * ps, [pa])
    pool.claim(1, 1)
    pool.ensure(1, 1)
    pb = pool.assigned[1][0]
    pool.publish([2] * ps, [pb])
    pool.release(0)
    pool.release(1)
    assert pool.cached == {pa, pb}
    return pool, pa, pb


def test_bytes_weighted_lru_uniform_cost_is_plain_lru():
    """With a uniform page cost (or none), the victim is the plain LRU
    minimum: the staler page goes first regardless of the byte weight."""
    for kwargs in ({}, {"page_bytes": 512},
                   {"page_bytes": 512, "override": None}):
        pool, pa, pb = _two_cached_pages(**kwargs)
        pool.evict(1)
        assert pa not in pool.cached and pb in pool.cached


def test_bytes_weighted_lru_prefers_evicting_expensive_page():
    """A cheap stale page (e.g. a cached int8 page at half the bf16
    bytes) survives over an expensive newer one when the byte ratio
    outweighs the recency ratio: victim minimizes clock/cost exactly."""
    pool, pa, pb = _two_cached_pages(page_bytes=100,
                                     override=None)
    # A is stale but cheap (quantized), B newer but 8x the bytes:
    # clock_a/50 > clock_b/400 for adjacent clocks -> B is the victim.
    pool.page_cost_override[pa] = 50
    pool.page_cost_override[pb] = 400
    pool.evict(1)
    assert pb not in pool.cached and pa in pool.cached
    # ledger conservation survives the weighted eviction
    assert pool.num_free + pool.num_referenced + pool.num_cached \
        == pool.num_pages


def test_bytes_weighted_lru_tie_breaks_on_lowest_page_id():
    """Exactly equal clock/cost scores fall back to the lowest page id
    (sorted iteration + strict <), keeping eviction deterministic."""
    from repro.serving.radix import RadixIndex
    idx = RadixIndex(2)
    idx.insert([1, 2, 3, 4], [7, 3])      # same tick => same clock
    assert idx.lru_page({7, 3}) == 3
    assert idx.lru_page({7, 3}, cost=lambda p: 9) == 3
