"""sample_steps / score_and_append invariants."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import build_model
from repro.sampling import sample_steps, score_and_append
from repro.sampling.sampler import PAD


def test_sample_steps_stop_and_logprob(tiny_dense):
    m = build_model(tiny_dense)
    params = m.init(jax.random.PRNGKey(0))
    B, sep, eos = 3, 1, 2
    cache = m.init_cache(B, 64)
    last = jnp.full((B,), 5, jnp.int32)
    pos = jnp.zeros((B,), jnp.int32)
    steps = sample_steps(m, params, cache, last, pos, jax.random.PRNGKey(1),
                         max_tokens=10, sep_token=sep, eos_token=eos,
                         temperature=1.0)
    toks = np.asarray(steps.tokens)
    for b in range(B):
        row = toks[b]
        ends = np.isin(row, [sep, eos])
        if ends.any():
            e = int(np.argmax(ends))
            assert (row[e + 1:] == PAD).all()      # nothing after step end
            assert steps.length[b] == e + 1
    assert steps.positions.shape == (B,)
    assert np.all(np.asarray(steps.positions) == np.asarray(steps.length))
    assert np.all(np.asarray(steps.logprob) <= 0.0)


def test_score_and_append_matches_sampling_logprob(tiny_dense):
    """Teacher-forcing the sampled step reproduces its sample logprob."""
    m = build_model(tiny_dense)
    params = m.init(jax.random.PRNGKey(0))
    B = 2
    last = jnp.full((B,), 5, jnp.int32)
    pos = jnp.zeros((B,), jnp.int32)
    steps = sample_steps(m, params, m.init_cache(B, 64), last, pos,
                         jax.random.PRNGKey(1), max_tokens=8, sep_token=1,
                         eos_token=2, temperature=1.0)
    lp, cache, pos2 = score_and_append(
        m, params, m.init_cache(B, 64), last, pos, steps.tokens)
    np.testing.assert_allclose(lp, steps.logprob, atol=1e-3, rtol=1e-3)
    assert np.all(np.asarray(pos2) == np.asarray(steps.positions))


def test_append_equals_prefill(tiny_dense):
    """Cache built by score_and_append == cache built by prefill."""
    m = build_model(tiny_dense)
    params = m.init(jax.random.PRNGKey(0))
    B, L = 2, 9
    seq = jax.random.randint(jax.random.PRNGKey(1), (B, L), 3, 60)
    _, cache_a, pos_a = score_and_append(
        m, params, m.init_cache(B, 16), seq[:, 0], jnp.zeros((B,), jnp.int32),
        seq[:, 1:])
    # invariant: cache holds positions < L-1, pending = seq[:, -1]
    _, cache_p = m.prefill(params, seq[:, :-1], max_seq=16)
    for a, p in zip(jax.tree.leaves(cache_a), jax.tree.leaves(cache_p)):
        np.testing.assert_allclose(np.asarray(a, np.float32)[..., :L - 1, :, :]
                                   if a.ndim >= 4 else np.asarray(a),
                                   np.asarray(p, np.float32)[..., :L - 1, :, :]
                                   if p.ndim >= 4 else np.asarray(p),
                                   atol=2e-4, rtol=2e-4)
    # continuing decode from both caches gives identical logits
    tok = seq[:, -1:]
    posv = jnp.full((B,), L - 1, jnp.int32)
    la, _ = m.decode_step(params, cache_a, tok, posv)
    lp_, _ = m.decode_step(params, cache_p, tok, posv)
    np.testing.assert_allclose(la, lp_, atol=2e-4, rtol=2e-4)


def test_score_and_append_variable_lengths(tiny_dense):
    """PAD rows freeze position and cache correctness for short steps."""
    m = build_model(tiny_dense)
    params = m.init(jax.random.PRNGKey(0))
    steps = jnp.array([[7, 8, 9, 10], [7, 1, PAD, PAD]], jnp.int32)
    last = jnp.full((2,), 5, jnp.int32)
    lp, cache, pos = score_and_append(
        m, params, m.init_cache(2, 16), last, jnp.zeros((2,), jnp.int32),
        steps)
    assert np.asarray(pos).tolist() == [4, 2]
