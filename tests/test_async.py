"""Asynchronous pipelined serving: identity, invariants, fleet threading.

Layers of coverage:
  * async == sync token identity at sampling temperature > 0 (the
    strictest parity check: per-step rng keys, slot bindings and
    admission order must all match) on dense and paged+prefix engines,
    and across full / local / hybrid stacks.
  * Deferred-release invariant: a slot freed at step k is re-admitted
    only after step k's ticket was materialized to host memory, and a
    slot bound by an in-flight ticket is never reacquired.
  * Thread-per-replica fleet loop: threaded async fleets reproduce the
    single-replica tokens under greedy decoding for every policy, and
    responses assemble across replicas.
  * EngineStats is safe under concurrent replica threads (hammer test).
  * Scheduler idle handling waits out exact arrival gaps on a condition
    variable instead of a capped sleep poll.
  * Rendezvous preamble hashing moves only ~1/N of chunks (all onto the
    new replica) when the fleet grows.
"""
import dataclasses
import threading
import time

import jax
import numpy as np
import pytest

from repro.config import GSIConfig, ModelConfig
from repro.models import build_model
from repro.serving import (EngineStats, GSIScheduler, GSIServingEngine,
                           ReplicaRouter, preamble_rendezvous)

PAD = 0

PRE_A = np.asarray([5 + (i % 24) for i in range(17)], np.int32)
PRE_B = np.asarray([30 + (i % 20) for i in range(17)], np.int32)


def _prompt(pre, tail):
    return np.concatenate([pre, np.asarray(tail, np.int32)])


def _triple(draft):
    target = dataclasses.replace(draft, name=draft.name + "-t",
                                 num_layers=3)
    prm = dataclasses.replace(target, name=draft.name + "-p",
                              reward_head=True)
    params = (build_model(draft).init(jax.random.PRNGKey(0)),
              build_model(target).init(jax.random.PRNGKey(1)),
              build_model(prm).init(jax.random.PRNGKey(2)))
    return (draft, target, prm), params


@pytest.fixture(scope="module")
def triple(tiny_triple):
    draft, target, prm = tiny_triple
    params = (build_model(draft).init(jax.random.PRNGKey(0)),
              build_model(target).init(jax.random.PRNGKey(1)),
              build_model(prm).init(jax.random.PRNGKey(2)))
    return (draft, target, prm), params


@pytest.fixture(scope="module")
def gcfg():
    # temperature > 0: sampled trajectories depend on the exact rng key
    # and slot binding of every step — the identity tests below only
    # pass if the pipeline preserves both
    return GSIConfig(n=2, max_step_tokens=5, max_steps=3, beta=4.0,
                     min_step_reward=-1.0)


@pytest.fixture(scope="module")
def greedy(gcfg):
    return dataclasses.replace(gcfg, temperature=0.0)


def _engine(triple, g, **kw):
    cfgs, params = triple
    return GSIServingEngine(*cfgs, *params, g, max_seq=96, **kw)


def _serve(engine, prompts, budgets, *, sync, capacity=2, seed=42,
           cache_aware=False):
    sched = GSIScheduler(engine, capacity=capacity, sync=sync,
                         cache_aware=cache_aware)
    ids = [sched.submit(p, request_id=f"r{i}", max_steps=budgets[i])
           for i, p in enumerate(prompts)]
    out = sched.run(jax.random.PRNGKey(seed))
    tokens = {r: out[r].tokens.tolist() for r in ids}
    reasons = {r: out[r].finish_reason for r in ids}
    return tokens, reasons, sched


# ----------------------------------------------------------------------
# async == sync identity
# ----------------------------------------------------------------------

def test_async_equals_sync_dense_sampling(triple, gcfg):
    """Bit-identical tokens at temperature > 0 on the dense engine."""
    prompts = [np.asarray([5, 6, 7, 4 + i], np.int32) for i in range(6)]
    budgets = [1, 3, 2, 3, 1, 2]
    tok_s, fin_s, sched_s = _serve(_engine(triple, gcfg), prompts,
                                   budgets, sync=True)
    tok_a, fin_a, sched_a = _serve(_engine(triple, gcfg), prompts,
                                   budgets, sync=False)
    assert tok_a == tok_s
    assert fin_a == fin_s
    assert sched_a.engine_steps == sched_s.engine_steps
    for f in ("steps", "accepted", "decisions", "draft_tokens",
              "target_tokens", "requests_finished"):
        assert getattr(sched_a.stats, f) == getattr(sched_s.stats, f), f


def test_async_equals_sync_paged_prefix(triple, gcfg):
    """Radix lookups, page claims and eviction all ride the pipeline:
    tokens AND prefix-cache counters must match the sync run."""
    prompts = [_prompt(PRE_A, [33 + i, 34, 4]) for i in range(4)] + \
              [_prompt(PRE_B, [43 + i, 44, 4]) for i in range(2)]
    budgets = [1, 2, 1, 2, 1, 2]
    runs = {}
    for sync in (True, False):
        eng = _engine(triple, gcfg, paged=True, page_size=8)
        runs[sync] = _serve(eng, prompts, budgets, sync=sync,
                            cache_aware=True)
    assert runs[False][0] == runs[True][0]
    assert runs[False][2].prefix_stats() == runs[True][2].prefix_stats()
    assert runs[False][2].engine_steps == runs[True][2].engine_steps
    assert runs[False][2].pipeline_stats()["overlap_host_s"] > 0


@pytest.mark.parametrize("pattern,window", [
    (("full",), 0),
    (("full", "local"), 12),
    (("recurrent", "full"), 0),
])
def test_async_equals_sync_across_stacks(gcfg, pattern, window):
    """full / sliding-window / hybrid-recurrent stacks: the pipeline is
    layout-agnostic (hybrid auto-disables prefix sharing but must still
    match its own sync run bit-for-bit)."""
    base = ModelConfig(
        name=f"t-async-{'-'.join(pattern)}-{window}", family="dense"
        if "recurrent" not in pattern else "hybrid",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
        vocab_size=64, head_dim=16, dtype="float32", param_dtype="float32",
        layer_pattern=pattern, window_size=window or 4096)
    triple = _triple(base)
    prompts = [_prompt(PRE_A, [33 + i, 34, 4]) for i in range(4)]
    budgets = [1, 2, 2, 1]
    tok_s, _, _ = _serve(_engine(triple, gcfg, paged=True, page_size=8),
                         prompts, budgets, sync=True)
    tok_a, _, _ = _serve(_engine(triple, gcfg, paged=True, page_size=8),
                         prompts, budgets, sync=False)
    assert tok_a == tok_s


@pytest.mark.parametrize("policy", ["affinity", "round_robin"])
def test_async_fleet_equals_single_replica(triple, greedy, policy):
    """Threaded async fleet == single sync replica (greedy decoding)."""
    prompts = [_prompt(PRE_A, [33, 34, 4]), _prompt(PRE_A, [35, 36, 4]),
               _prompt(PRE_B, [37, 38, 4]), _prompt(PRE_B, [39, 40, 4])]
    budgets = [1, 2, 1, 2]
    tok_single, _, _ = _serve(
        _engine(triple, greedy, paged=True, page_size=8), prompts,
        budgets, sync=True, capacity=1, seed=3)
    router = ReplicaRouter(
        [_engine(triple, greedy, paged=True, page_size=8)
         for _ in range(2)],
        capacity=1, policy=policy, skew=None, sync=False, threaded=True)
    for i, p in enumerate(prompts):
        router.submit(p, request_id=f"r{i}", max_steps=budgets[i])
    out = router.run(jax.random.PRNGKey(91))
    assert {r: resp.tokens.tolist() for r, resp in out.items()} \
        == tok_single, policy
    assert router.pipeline_stats()["sync"] is False


def test_fleet_thread_failure_aborts_run(triple, greedy, monkeypatch):
    """A replica thread that dies must abort run() with the error, not
    hang the fleet loop forever."""
    router = ReplicaRouter(
        [_engine(triple, greedy, paged=True, page_size=8)
         for _ in range(2)],
        capacity=1, policy="round_robin", sync=False, threaded=True)
    boom = router.replicas[0].scheduler

    def explode(*a, **kw):
        raise ValueError("injected replica failure")

    monkeypatch.setattr(boom, "step", explode)
    for i in range(2):
        router.submit(_prompt(PRE_A, [33 + i, 34, 4]),
                      request_id=f"r{i}", max_steps=1)
    with pytest.raises(RuntimeError, match="fleet-loop thread failed"):
        router.run(jax.random.PRNGKey(1))


def test_async_step_api_drains_pipeline(triple, gcfg):
    """Step-wise async driving: responses lag by one step while the
    pipeline is full, and repeated step() calls drain everything."""
    sched = GSIScheduler(_engine(triple, gcfg), capacity=1, sync=False)
    first = sched.submit([5, 6, 4], max_steps=1)
    second = sched.submit([7, 3, 4], max_steps=1)
    rng = jax.random.PRNGKey(0)
    finished = []
    for _ in range(16):
        rng, k = jax.random.split(rng)
        finished.extend(r.request_id for r in sched.step(k))
        if len(finished) == 2:
            break
    assert finished == [first, second]
    assert not sched.has_pending
    assert sched.engine_steps == 2


# ----------------------------------------------------------------------
# Deferred-release invariant
# ----------------------------------------------------------------------

def test_deferred_release_slot_reuse(triple, gcfg, monkeypatch):
    """A slot freed at step k is re-admitted only after step k's ticket
    was materialized (its final tokens live on the host), and never
    while its ticket is still in flight."""
    eng = _engine(triple, gcfg, paged=True, page_size=8)
    sched = GSIScheduler(eng, capacity=1, sync=False)
    events = []

    real_materialize = eng.materialize
    real_claim = eng.claim_slot

    def spy_materialize(ticket):
        events.append(("materialize",))
        return real_materialize(ticket)

    def spy_claim(slot, *a, **kw):
        # the engine-side reacquisition point of a freed slot
        assert sched._inflight is None or \
            slot not in sched._inflight.bound, \
            "slot reacquired while its step is still in flight"
        events.append(("claim", slot))
        return real_claim(slot, *a, **kw)

    monkeypatch.setattr(eng, "materialize", spy_materialize)
    monkeypatch.setattr(eng, "claim_slot", spy_claim)

    for i in range(3):
        sched.submit(_prompt(PRE_A, [33 + i, 34, 4]), request_id=f"r{i}",
                     max_steps=1)
    out = sched.run(jax.random.PRNGKey(5))
    assert set(out) == {"r0", "r1", "r2"}
    # slot 0 is claimed three times; each re-claim must be preceded by
    # one more materialize than the previous claim (release deferred
    # until the freeing step's ticket is on the host)
    claims = [i for i, e in enumerate(events) if e[0] == "claim"]
    assert len(claims) == 3
    for prev, nxt in zip(claims, claims[1:]):
        between = [e for e in events[prev:nxt] if e[0] == "materialize"]
        assert between, "slot re-claimed before the freeing step's harvest"


def test_async_respects_page_backpressure(triple, gcfg):
    """Deferral under page pressure behaves like the sync scheduler:
    requests queue (never drop) and all finish."""
    eng = _engine(triple, gcfg, paged=True, page_size=8, num_pages=8)
    sched = GSIScheduler(eng, capacity=2, sync=False)
    ids = [sched.submit(_prompt(PRE_A, [33 + i, 34, 4]),
                        request_id=f"r{i}", max_steps=2)
           for i in range(4)]
    out = sched.run(jax.random.PRNGKey(11))
    assert set(out) == set(ids)
    pool = eng.pager
    assert pool.num_free + pool.num_referenced + pool.num_cached \
        == pool.num_pages


# ----------------------------------------------------------------------
# EngineStats thread safety
# ----------------------------------------------------------------------

def test_engine_stats_concurrent_hammer():
    """Counters and moment folds stay exact under thread contention."""
    stats = EngineStats(trace_limit=8)
    threads, per, nthreads = [], 200, 8

    def work():
        for i in range(per):
            stats.bump(steps=1, draft_tokens=2)
            stats.record_trace("raw_rewards",
                               np.asarray([float(i % 7), 1.0]))

    for _ in range(nthreads):
        threads.append(threading.Thread(target=work))
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert stats.steps == nthreads * per
    assert stats.draft_tokens == 2 * nthreads * per
    assert stats.trace_count("raw_rewards") == 2 * nthreads * per
    # mean over {0..6} cycled with a constant 1.0 partner value
    want = (np.mean([i % 7 for i in range(per)]) + 1.0) / 2.0
    np.testing.assert_allclose(stats.trace_mean("raw_rewards"), want)
    assert len(stats.raw_rewards) == 8        # bounded trace kept


# ----------------------------------------------------------------------
# Idle handling
# ----------------------------------------------------------------------

@pytest.mark.parametrize("sync", [True, False])
def test_idle_wait_is_condition_based_not_sleep_poll(triple, gcfg, sync,
                                                     monkeypatch):
    """Arrival gaps are waited out on a condition variable: run() never
    calls time.sleep, a sub-50ms gap is not rounded up to a poll tick,
    and a submit from another thread wakes the idle wait early."""
    import repro.serving.scheduler as sched_mod

    def no_sleep(_):
        raise AssertionError("run() must not sleep-poll idle gaps")

    sched = GSIScheduler(_engine(triple, gcfg), capacity=1, sync=sync)
    sched.submit([5, 6, 4], max_steps=1)                  # warm compile
    sched.run(jax.random.PRNGKey(0))
    monkeypatch.setattr(sched_mod.time, "sleep", no_sleep)
    # a 20ms arrival gap with an empty pool: the old loop slept in
    # capped 50ms ticks, the new one waits exactly the gap on the cv
    sched.submit([5, 6, 4], request_id="near", max_steps=1,
                 arrival_time=0.02)
    # a second thread submits an immediate request while run() is
    # parked — the cv wake must pick it up without polling.  (time.sleep
    # is globally patched to raise, so the delay uses an Event wait.)
    def late_submit():
        threading.Event().wait(0.005)
        sched.submit([7, 3, 4], request_id="now", max_steps=1)

    t = threading.Thread(target=late_submit)
    t.start()
    out = sched.run(jax.random.PRNGKey(1))
    t.join()
    assert {"near", "now"} <= set(out)


# ----------------------------------------------------------------------
# Rendezvous hashing
# ----------------------------------------------------------------------

def test_rendezvous_bounded_movement_2_to_3():
    """Growing the fleet 2 -> 3 remaps only ~1/3 of preamble chunks and
    every moved chunk lands on the new replica."""
    chunks = [np.random.default_rng(i).integers(1, 60, 16)
              for i in range(400)]
    p2 = [preamble_rendezvous(c, 2) for c in chunks]
    p3 = [preamble_rendezvous(c, 3) for c in chunks]
    moved = [(a, b) for a, b in zip(p2, p3) if a != b]
    assert all(b == 2 for _, b in moved), \
        "rendezvous moved a chunk between surviving replicas"
    frac = len(moved) / len(chunks)
    assert 0.15 < frac < 0.55, frac       # ~1/3 expected
    # determinism
    assert p3 == [preamble_rendezvous(c, 3) for c in chunks]


def test_rendezvous_router_tier(triple, greedy):
    """hash_tier=rendezvous drives tier-2 placement deterministically."""
    engines = [_engine(triple, greedy, paged=True, page_size=8)
               for _ in range(2)]
    router = ReplicaRouter(engines, capacity=1, policy="affinity",
                           skew=None, hash_tier="rendezvous",
                           threaded=False)
    want = preamble_rendezvous(PRE_A[:8], 2)
    rid = router.submit(_prompt(PRE_A, [33, 34, 4]), max_steps=1)
    assert router.replica_of(rid) == want
    assert router.routing["affinity_hashed"] == 1
    with pytest.raises(ValueError):
        ReplicaRouter([_engine(triple, greedy, paged=True, page_size=8)],
                      capacity=1, hash_tier="nope")
