"""Refcounted radix prefix cache: cross-request KV sharing + eviction.

Layers of coverage:
  * RadixIndex trie semantics (match / insert dedupe / LRU / subtree drop).
  * PagePool refcount ledger: shared claims, release survival (live readers
    and retained cache entries), claim-time LRU eviction, pinning of
    matched pages against the eviction the same claim triggers.
  * End-to-end token identity: dense == paged == paged+prefix through the
    continuous-batching scheduler on full / sliding-window stacks, with
    hit-rate > 0 and strictly fewer prefill commits when sharing is on;
    hybrid recurrent stacks auto-disable sharing and stay identical.
  * Shared pages are never written by later readers (content snapshot).
  * Pool pressure: admission evicts cached pages instead of deferring.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.config import GSIConfig, ModelConfig
from repro.models import build_model
from repro.serving import (GSIScheduler, GSIServingEngine, PagePool,
                           RadixIndex, pack_tails)

PAD = 0


def _triple(draft):
    target = dataclasses.replace(draft, name=draft.name + "-t", num_layers=3)
    prm = dataclasses.replace(target, name=draft.name + "-p",
                              reward_head=True)
    params = (build_model(draft).init(jax.random.PRNGKey(0)),
              build_model(target).init(jax.random.PRNGKey(1)),
              build_model(prm).init(jax.random.PRNGKey(2)))
    return (draft, target, prm), params


@pytest.fixture(scope="module")
def gcfg():
    return GSIConfig(n=2, max_step_tokens=5, max_steps=3, beta=4.0,
                     min_step_reward=-1.0)


@pytest.fixture(scope="module")
def dense_triple(tiny_dense):
    return _triple(tiny_dense)


# 2 full pages (ps=8) of shared preamble + distinct per-request tails
PRE = np.asarray([5 + (i % 24) for i in range(17)], np.int32)


def _prompt(tail):
    return np.concatenate([PRE, np.asarray(tail, np.int32)])


# ----------------------------------------------------------------------
# RadixIndex
# ----------------------------------------------------------------------

def test_radix_match_insert_dedupe():
    idx = RadixIndex(page_size=4)
    toks = list(range(10, 22))            # 3 full chunks
    assert idx.match(toks) == ([], 0)
    assert idx.insert(toks, [7, 3, 9]) == [7, 3, 9]
    pages, m = idx.match(toks)
    assert pages == [7, 3, 9] and m == 12
    # shorter query matches its page-aligned prefix only
    assert idx.match(toks[:7]) == ([7], 4)
    # diverging chunk stops the walk
    other = toks[:4] + [99, 99, 99, 99]
    assert idx.match(other) == ([7], 4)
    # duplicate chunks keep the first writer's page
    assert idx.insert(toks[:8], [11, 12]) == []
    assert idx.match(toks[:8]) == ([7, 3], 8)
    # extending under an existing path registers only the new chunk
    assert idx.insert(other, [11, 13]) == [13]
    assert idx.match(other) == ([7, 13], 8)


def test_radix_lru_and_subtree_drop():
    idx = RadixIndex(page_size=2)
    idx.insert([1, 2, 3, 4], [0, 1])      # chain 0 -> 1
    # (1,2) deduped against page 0; (9,9) registers page 2 under it
    assert idx.insert([1, 2, 9, 9], [5, 2]) == [2]
    assert idx.match([1, 2, 9, 9])[0] == [0, 2]
    idx.match([1, 2, 3, 4])               # touch the 3,4 branch (newer)
    assert idx.lru_page({1, 2}) == 2      # 9,9 branch is now LRU
    dropped = idx.drop_subtree(0)         # root chunk: whole trie goes
    assert sorted(dropped) == [0, 1, 2]
    assert idx.match([1, 2, 3, 4]) == ([], 0)
    assert len(idx) == 0


# ----------------------------------------------------------------------
# PagePool refcounts, retention, eviction
# ----------------------------------------------------------------------

def test_shared_claim_refcounts_and_release_order():
    pool = PagePool(6, page_size=4, index=RadixIndex(4))
    pool.claim(0, 3)
    pool.ensure(0, 3)
    owned = list(pool.assigned[0])
    pool.publish(list(range(20, 28)), owned[:2])   # 2 full pages cached
    # second slot splices the two shared pages, claims only a 1-page tail
    pool.claim(1, 1, shared=owned[:2])
    assert pool.refcount[owned[0]] == 2 and pool.refcount[owned[1]] == 2
    pool.ensure(1, 3)
    assert pool.assigned[1][:2] == owned[:2]
    # first reader leaves: shared pages survive with live readers
    pool.release(0)
    assert pool.refcount[owned[0]] == 1
    assert owned[2] in pool.free          # unshared, unretained -> freed
    # last reader leaves: retained pages park in the cached LRU set
    pool.release(1)
    assert owned[0] not in pool.free and owned[0] in pool.cached
    assert pool.num_referenced == 0
    assert pool.num_free + pool.num_cached == pool.num_pages


def test_conservation_and_eviction_under_pressure():
    pool = PagePool(4, page_size=4, index=RadixIndex(4))
    pool.claim(0, 4)
    pool.ensure(0, 4)
    pool.publish(list(range(40, 56)), pool.assigned[0])
    pool.release(0)
    assert pool.num_cached == 4 and pool.num_free == 0
    # a fresh 3-page claim must evict 3 LRU cached pages, not defer
    assert pool.can_claim(3)
    pool.claim(1, 3)
    assert pool.evicted >= 3 and pool.num_free >= 3
    assert pool.num_free + pool.num_referenced + pool.num_cached \
        == pool.num_pages
    # ... and the evicted chunks are gone from the index
    assert len(pool.index) == pool.num_cached


def test_claim_pins_matched_pages_before_evicting():
    """free=0, 3 cached, 2 of them matched: tail claim of 2 must evict
    only the unmatched page and fail (insufficient), never evict pinned
    matched pages and 'succeed'."""
    pool = PagePool(3, page_size=4, index=RadixIndex(4))
    pool.claim(0, 3)
    pool.ensure(0, 3)
    pool.publish(list(range(30, 42)), pool.assigned[0])
    pool.release(0)
    matched, m = pool.match(list(range(30, 42)))
    assert m == 12 and len(matched) == 3
    shared = matched[:2]
    assert not pool.can_claim(2, shared)   # only 1 page truly evictable
    with pytest.raises(ValueError):
        pool.claim(1, 2, shared=shared)
    # failed claim unwound its pins: nothing referenced, ledger intact
    assert pool.num_referenced == 0
    assert pool.num_free + pool.num_cached == pool.num_pages
    # the fitting claim succeeds by evicting the one unmatched page
    matched, _ = pool.match(list(range(30, 42)))
    shared = matched[:2]
    assert pool.can_claim(1, shared)
    pool.claim(1, 1, shared=shared)
    assert pool.refcount[shared[0]] == 1 and pool.num_free >= 1


def test_publish_requires_live_reference():
    """Retaining a free page would let the trie serve it while ensure()
    hands it to a new writer — publish must reject that outright."""
    pool = PagePool(4, page_size=4, index=RadixIndex(4))
    pool.claim(0, 2)
    pool.ensure(0, 2)
    owned = list(pool.assigned[0])
    pool.release(0)                       # unretained -> both pages freed
    with pytest.raises(ValueError):
        pool.publish(list(range(8)), owned)
    assert pool.num_free == 4 and not pool.retained


def test_pack_tails_shifts_rows():
    prompts = np.asarray([[3, 4, 5, 6, PAD], [7, 8, 9, PAD, PAD]], np.int32)
    tails = pack_tails(prompts, np.asarray([2, 0]))
    np.testing.assert_array_equal(tails[0], [5, 6, PAD, PAD, PAD])
    np.testing.assert_array_equal(tails[1], prompts[1])
    with pytest.raises(ValueError):
        pack_tails(prompts, np.asarray([5, 0]))


# ----------------------------------------------------------------------
# End-to-end: token identity + measured sharing
# ----------------------------------------------------------------------

def _sched_run(engine, prompts, *, capacity=2, budgets=None, seed=7):
    sched = GSIScheduler(engine, capacity=capacity)
    ids = [sched.submit(p, max_steps=None if budgets is None else budgets[i])
           for i, p in enumerate(prompts)]
    out = sched.run(jax.random.PRNGKey(seed))
    return {r: out[r].tokens.tolist() for r in ids}, sched


def _stack_triple(pattern, window):
    base = ModelConfig(
        name=f"t-px-{'-'.join(pattern)}-{window}", family="dense"
        if "recurrent" not in pattern else "hybrid",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
        vocab_size=64, head_dim=16, dtype="float32", param_dtype="float32",
        layer_pattern=pattern, window_size=window or 4096)
    return _triple(base)


@pytest.mark.parametrize("pattern,window", [
    (("full",), 0),
    (("full", "local"), 12),
])
def test_prefix_sharing_token_identical_and_hits(gcfg, pattern, window):
    cfgs, params = _stack_triple(pattern, window)
    prompts = [_prompt([33, 34, 4]), _prompt([35, 36, 4]),
               _prompt([37, 38, 4]), _prompt([39, 40, 4])]
    runs, scheds = {}, {}
    for name, paged, prefix in [("dense", False, False),
                                ("paged", True, False),
                                ("prefix", True, True)]:
        eng = GSIServingEngine(*cfgs, *params, gcfg, max_seq=96,
                               paged=paged, page_size=8,
                               prefix_cache=prefix)
        runs[name], scheds[name] = _sched_run(eng, prompts)
    assert runs["dense"] == runs["paged"] == runs["prefix"]
    ps_on = scheds["prefix"].prefix_stats()
    ps_off = scheds["paged"].prefix_stats()
    # the first admission batch fills both slots against an empty index;
    # every request admitted after it matches the 2 full preamble pages
    assert ps_on["hits"] >= 2 and ps_on["hit_rate"] > 0
    assert ps_on["hit_tokens"] >= 2 * 16
    assert ps_on["pages_reused"] >= 4
    assert ps_on["prefill_tokens"] < ps_off["prefill_tokens"]
    assert ps_off["hits"] == 0


def test_hybrid_stack_auto_disables_sharing_and_stays_identical(gcfg):
    cfgs, params = _stack_triple(("recurrent", "full"), 0)
    prompts = [_prompt([33, 34, 4]), _prompt([35, 36, 4])]
    eng_on = GSIServingEngine(*cfgs, *params, gcfg, max_seq=96, paged=True,
                              page_size=8, prefix_cache=True)
    eng_off = GSIServingEngine(*cfgs, *params, gcfg, max_seq=96, paged=True,
                               page_size=8, prefix_cache=False)
    assert not eng_on.prefix_cache       # recurrent state cannot be spliced
    on, sched_on = _sched_run(eng_on, prompts)
    off, _ = _sched_run(eng_off, prompts)
    assert on == off
    assert sched_on.prefix_stats()["hits"] == 0


def test_identical_prompt_reuses_pages_across_slot_recycling(dense_triple,
                                                             gcfg):
    """The same prompt resubmitted after its first run finishes must splice
    the cached pages (hit) and commit strictly fewer prefill tokens."""
    cfgs, params = dense_triple
    eng = GSIServingEngine(*cfgs, *params, gcfg, max_seq=96, paged=True,
                           page_size=8)
    assert eng.prefix_cache
    prompt = _prompt([33, 34, 4])
    sched = GSIScheduler(eng, capacity=1)
    a = sched.submit(prompt, max_steps=2)
    b = sched.submit(prompt, max_steps=2)
    out = sched.run(jax.random.PRNGKey(3))
    assert a in out and b in out
    st = sched.prefix_stats()
    assert st["queries"] == 2 and st["hits"] == 1
    assert st["hit_tokens"] == 16        # both full preamble pages
    assert st["pages_reused"] == 2
    # reused pages were never re-prefilled: total commits < 2 full prompts
    assert st["prefill_tokens"] == 2 * (prompt.size - 1) - 16


def _pool_pages(cache, pages):
    """Gather every paged K/V pool leaf at ``pages`` (stacked leaves carry
    a leading repeats dim; page ids index the pool axis)."""
    out = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(cache)[0]:
        keys = [getattr(p, "key", None) for p in path]
        if "kp" not in keys and "vp" not in keys:
            continue
        axis = 1 if "blocks" in keys else 0
        out.append(np.asarray(jax.numpy.take(leaf, np.asarray(pages),
                                             axis=axis)))
    assert out
    return out


def test_shared_pages_survive_reader_and_content_is_never_touched(
        dense_triple, gcfg):
    """Snapshot the matched pages' K/V rows after the writer finishes; a
    second request splicing them must leave every byte intact."""
    cfgs, params = dense_triple
    eng = GSIServingEngine(*cfgs, *params, gcfg, max_seq=96, paged=True,
                           page_size=8)
    sched = GSIScheduler(eng, capacity=1)
    a = sched.submit(_prompt([33, 34, 4]), max_steps=2)
    rng = jax.random.PRNGKey(11)
    done = []
    while not done:
        rng, k = jax.random.split(rng)
        done = sched.step(k)
    assert [r.request_id for r in done] == [a]
    cached = sorted(eng.pager.cached)
    # >= 2: decode-time publication also caches generated-trajectory pages
    assert len(cached) >= 2
    before = _pool_pages(sched.state["caches"], cached)
    b = sched.submit(_prompt([35, 36, 4]), max_steps=2)
    while b not in sched.responses:
        rng, k = jax.random.split(rng)
        sched.step(k)
    after = _pool_pages(sched.state["caches"], cached)
    for x, y in zip(before, after):
        np.testing.assert_array_equal(x, y)


# ----------------------------------------------------------------------
# Pool pressure: evict-over-defer (the acceptance criterion)
# ----------------------------------------------------------------------

def test_admission_evicts_cached_pages_instead_of_deferring(dense_triple,
                                                            gcfg):
    """Pool sized so the second (different-prefix) request only fits if the
    first one's cached pages are evicted: it must be admitted on the very
    next step after the first finishes — eviction, not deferral."""
    cfgs, params = dense_triple
    # blocks_needed(20, 2) = pages_for(19 + 10 + 1, 8) = 4 pages
    eng = GSIServingEngine(*cfgs, *params, gcfg, max_seq=96, paged=True,
                           page_size=8, num_pages=4)
    sched = GSIScheduler(eng, capacity=2)
    pre_b = np.asarray([40 + (i % 10) for i in range(17)], np.int32)
    a = sched.submit(_prompt([33, 34, 4]), max_steps=2)
    rng = jax.random.PRNGKey(5)
    done = []
    while not done:
        rng, k = jax.random.split(rng)
        done = sched.step(k)
    assert [r.request_id for r in done] == [a]
    assert eng.pager.num_cached >= 2      # preamble pages retained (plus
    #                                       decode-published trajectory)
    b = sched.submit(np.concatenate([pre_b, [35, 36, 4]]), max_steps=2)
    rng, k = jax.random.split(rng)
    sched.step(k)
    # admitted immediately: the queue is empty and pages were evicted
    assert len(sched.queue) == 0 and sched.pool.request_of(0) is not None
    assert eng.pager.evicted >= 1
    assert sched.prefix_stats()["pages_evicted"] >= 1
    while b not in sched.responses:
        rng, k = jax.random.split(rng)
        sched.step(k)


def test_fresh_state_resets_prefix_index(dense_triple, gcfg):
    cfgs, params = dense_triple
    eng = GSIServingEngine(*cfgs, *params, gcfg, max_seq=96, paged=True,
                           page_size=8)
    _sched_run(eng, [_prompt([33, 34, 4])], capacity=1)
    assert eng.pager.num_cached > 0
    eng.fresh_state(1)                    # new state -> empty index
    assert eng.pager.num_cached == 0 and eng.pager.num_free == eng.num_pages
    assert eng.match_prefix(_prompt([33, 34, 4])) == ([], 0)
