"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.logprob_gather import logprob_gather_pallas
from repro.kernels.rwkv6_scan import rwkv6_scan_pallas


@pytest.mark.parametrize("B,S,d,V,vocab,dtype", [
    (2, 8, 64, 512, 500, jnp.float32),
    (1, 17, 128, 1024, 1024, jnp.float32),
    (3, 5, 32, 768, 700, jnp.bfloat16),
    (1, 1, 16, 256, 256, jnp.float32),
])
def test_logprob_gather(B, S, d, V, vocab, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(V + S), 3)
    h = jax.random.normal(k1, (B, S, d), dtype)
    w = (jax.random.normal(k2, (d, V), jnp.float32) * 0.05).astype(dtype)
    lab = jax.random.randint(k3, (B, S), 0, vocab)
    out = logprob_gather_pallas(h, w, lab, vocab, tt=8, vt=256,
                                interpret=True)
    want = ref.logprob_gather_ref(h, w, lab, vocab)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(out, want, atol=tol, rtol=tol)


@pytest.mark.parametrize("B,Sq,H,KV,hd,causal,window,dtype", [
    (2, 32, 4, 2, 16, True, 0, jnp.float32),
    (1, 40, 3, 1, 32, True, 16, jnp.float32),
    (2, 24, 2, 2, 8, False, 0, jnp.float32),
    (1, 33, 4, 4, 16, True, 0, jnp.bfloat16),
])
def test_flash_attention(B, Sq, H, KV, hd, causal, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(Sq + H), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, Sq, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, Sq, KV, hd), dtype)
    out = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 qt=16, kt=16, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    tol = 3e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("B,T,H,hd,chunk", [
    (2, 24, 3, 8, 8),
    (1, 17, 2, 16, 8),   # ragged T vs chunk
    (2, 32, 1, 4, 16),
    (1, 8, 2, 8, 64),    # chunk > T
])
def test_rwkv6_scan(B, T, H, hd, chunk):
    ks = jax.random.split(jax.random.PRNGKey(T + hd), 6)
    r, k, v = (jax.random.normal(ks[i], (B, T, H, hd)) for i in range(3))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, H, hd))) * 0.5 + 0.45
    u = jax.random.normal(ks[4], (H, hd)) * 0.3
    s0 = jax.random.normal(ks[5], (B, H, hd, hd)) * 0.1
    out, sT = rwkv6_scan_pallas(r, k, v, w, u, s0, chunk=chunk,
                                interpret=True)
    oref, sref = ref.rwkv6_scan_ref(r, k, v, w, u, s0)
    np.testing.assert_allclose(out, oref, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(sT, sref, atol=1e-4, rtol=1e-4)


def test_ops_dispatch_interpret(monkeypatch):
    """REPRO_USE_PALLAS=interpret routes through the kernels."""
    monkeypatch.setenv("REPRO_USE_PALLAS", "interpret")
    from repro.kernels import ops
    h = jax.random.normal(jax.random.PRNGKey(0), (1, 4, 32))
    w = jax.random.normal(jax.random.PRNGKey(1), (32, 256)) * 0.1
    lab = jax.random.randint(jax.random.PRNGKey(2), (1, 4), 0, 256)
    np.testing.assert_allclose(
        ops.logprob_gather(h, w, lab, 256),
        ref.logprob_gather_ref(h, w, lab, 256), atol=1e-4, rtol=1e-4)
