"""Extra coverage: shared-prefix scoring edges, ring slots, n_target,
MoE capacity drops, latency-model consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config, reduced_config
from repro.core import ToyEnv
from repro.models import build_model
from repro.models.scoring import _slot_abs_positions, score_candidates
from repro.sampling import score_and_append
from repro.serving.engine import expand_requests, repeat_cache


def test_slot_abs_positions_full_and_ring():
    # full cache (size >= pos): slot j holds position j for j < pos
    pos = jnp.array([5])
    a = np.asarray(_slot_abs_positions(pos, 8))[0]
    assert a[:5].tolist() == [0, 1, 2, 3, 4]
    assert (a[5:] < 0).all()
    # ring cache size 4, pos=10: slots hold positions 6..9 at j = p % 4
    a = np.asarray(_slot_abs_positions(jnp.array([10]), 4))[0]
    for j in range(4):
        assert a[j] % 4 == j and 6 <= a[j] <= 9
    # empty cache
    a = np.asarray(_slot_abs_positions(jnp.array([0]), 4))[0]
    assert (a < 0).all()


def test_score_candidates_single_candidate(tiny_dense):
    """n=1 degenerate case equals direct teacher forcing."""
    cfg = dataclasses.replace(tiny_dense, reward_head=True)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, L = 2, 5
    prefix = jax.random.randint(jax.random.PRNGKey(1), (B, 6), 3, 60)
    _, cache = m.prefill(params, prefix[:, :-1], max_seq=24)
    pend, pos = prefix[:, -1], jnp.full((B,), 5, jnp.int32)
    cand = jax.random.randint(jax.random.PRNGKey(2), (B, 1, L), 3, 60)
    lp = score_candidates(m, params, cache, pend, pos, cand)
    lp_ref, _, _ = score_and_append(m, params, cache, pend, pos,
                                    cand[:, 0])
    np.testing.assert_allclose(lp[:, 0], lp_ref, atol=1e-3, rtol=1e-3)


def test_moe_capacity_drops_tokens():
    """With capacity_factor ~0 the routed contribution vanishes."""
    cfg = dataclasses.replace(
        reduced_config(get_config("qwen2-moe-a2.7b")),
        capacity_factor=1e-9, num_shared_experts=0)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 3,
                              cfg.vocab_size)
    logits, _ = m.forward(params, toks)
    assert jnp.isfinite(logits).all()  # drops degrade, never NaN


def test_toy_n_target_improves_reward():
    env = ToyEnv(m=12, seed=0)
    beta, u = 1.0, 0.5
    tilted = env.tilted(beta)

    def gap(nt):
        tr = env.run_gsi(jax.random.PRNGKey(nt), n=2, beta=beta, u=u,
                         trials=80_000, n_target=nt)
        er = float(jnp.sum(env.histogram(tr.outcomes) * env.r_star))
        return float(env.expected_golden(tilted)) - er

    assert gap(16) < gap(1)  # resampling-side n closes the r* gap


def test_latency_model_n_scaling():
    from repro.serving.latency import HW_V5E, LatencyModel, ModelCost
    lm = LatencyModel(ModelCost(1e9, 512), ModelCost(7e9, 2048),
                      ModelCost(7e9, 2048), HW_V5E)
    t4 = lm.step_time(method="gsi", n=4, step_len=50, ctx_len=512,
                      accept_rate=0.8)
    t64 = lm.step_time(method="gsi", n=64, step_len=50, ctx_len=512,
                       accept_rate=0.8)
    assert t64 > t4            # more candidates cost more
    assert t64 < 16 * t4       # but far sublinear (parallel scoring)


def test_engine_n_target(tiny_triple):
    from repro.config import GSIConfig
    from repro.serving import GSIServingEngine
    draft, target, prm = tiny_triple
    ps = build_model(draft).init(jax.random.PRNGKey(0))
    pb = build_model(target).init(jax.random.PRNGKey(1))
    pp = build_model(prm).init(jax.random.PRNGKey(2))
    g = GSIConfig(n=2, n_target=3, max_step_tokens=4, max_steps=2,
                  beta=4.0, threshold_u=100.0,  # force rejection
                  min_step_reward=-1.0)
    eng = GSIServingEngine(draft, target, prm, ps, pb, pp, g, max_seq=48)
    prompts = np.array([[5, 6, 4]], np.int32)
    responses, stats = eng.run(prompts, jax.random.PRNGKey(3))
    assert stats.accept_rate == 0.0        # everything resampled
    assert stats.steps >= 1
