"""Sharding rules + a real 8-device pjit/shard_map integration (subprocess).

The multi-device tests run in subprocesses because the placeholder
device count must be set before jax initializes (conftest keeps the main
test process on the single real CPU device).

Tensor-parallel *serving* coverage (the mesh engine):
  * sharded == unsharded BIT-IDENTICAL tokens through the scheduler —
    a 2-replica router where each replica owns a (data=1, model=2)
    submesh, sync AND async, greedy AND temperature>0 (subprocess);
  * the same identity across local and hybrid attention stacks;
  * sharding-spec assertions: target weights and target KV pool carry
    the ``model`` axis, draft/PRM stay replicated, submeshes disjoint;
  * an in-process (1,1)-mesh engine for tier-1 coverage of the
    shard_map decode path on the single real CPU device, including
    page-ledger conservation under the sharded pool.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.models.common import ParamSpec

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


class FakeMesh:
    axis_names = ("data", "model")

    class devices:  # noqa: N801
        shape = (4, 2)

    shape = {"data": 4, "model": 2}


def _pspec(shape, axes, mode="train"):
    from repro.distributed.sharding import spec_pspec
    return spec_pspec(ParamSpec(shape, axes, "normal", 1.0), FakeMesh(),
                      mode)


def test_divisibility_fallback():
    # heads=3 not divisible by model=2 -> replicated
    assert _pspec((64, 3, 16), ("embed", "heads", "head"))[1] is None
    # heads=4 divisible -> sharded
    assert _pspec((64, 4, 16), ("embed", "heads", "head"))[1] == "model"
    # embed FSDP over data in train mode
    assert _pspec((64, 4, 16), ("embed", "heads", "head"))[0] == "data"
    # serve mode: embed replicated
    assert _pspec((64, 4, 16), ("embed", "heads", "head"),
                  "serve")[0] is None


def test_no_axis_reuse_within_one_param():
    # expert -> model and expert_mlp -> data must not collide with embed
    p = _pspec((8, 64, 32), ("expert", "embed", "expert_mlp"))
    used = [a for a in p if a]
    assert len(used) == len(set(used))


def test_batch_pspec():
    from repro.distributed.sharding import batch_pspec
    assert batch_pspec(_mesh_like((4, 2), ("data", "model")), 8) == "data"
    assert batch_pspec(_mesh_like((4, 2), ("data", "model")), 3) is None


def _mesh_like(shape, axes):
    class M:
        axis_names = axes

        class devices:  # noqa: N801
            pass
    M.devices.shape = shape
    return M()


MULTIDEV_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.config import get_config, reduced_config, TrainConfig
    from repro.distributed import context as dctx
    from repro.distributed.sharding import (as_shardings, param_pspecs,
                                            batch_pspec)
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.models import build_model
    from repro.optim import AdamW
    from repro.train import make_train_step

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    # MoE arch exercises the shard_map expert-parallel path for real
    cfg = reduced_config(get_config("qwen2-moe-a2.7b"))
    model = build_model(cfg)
    tcfg = TrainConfig(total_steps=5, warmup_steps=1)
    with dctx.use_mesh(mesh):
        p_sh = as_shardings(param_pspecs(model.param_specs(), mesh,
                                         "train"), mesh)
        params = jax.jit(model.init, out_shardings=p_sh)(
            jax.random.PRNGKey(0))
        opt = AdamW(tcfg)
        opt_state = opt.init(params)
        step = jax.jit(make_train_step(cfg, tcfg))
        B, S = 8, 16
        batch = {
            "tokens": jnp.asarray(
                np.random.randint(3, cfg.vocab_size, (B, S)), jnp.int32),
            "loss_mask": jnp.ones((B, S), jnp.float32),
        }
        sh = NamedSharding(mesh, P("data", None))
        batch = {k: jax.device_put(v, sh) for k, v in batch.items()}
        for i in range(3):
            params, opt_state, m = step(params, opt_state, batch)
        loss = float(m["loss"])
        assert np.isfinite(loss), loss
        # both expert-parallel modes agree (H2's repl vs gather dispatch)
        model = build_model(cfg)
        outs = []
        for mode in ("gather", "repl"):
            os.environ["REPRO_MOE_MODE"] = mode
            lg, _ = jax.jit(model.forward)(params, batch["tokens"][:, :8])
            outs.append(np.asarray(lg, np.float32))
        os.environ["REPRO_MOE_MODE"] = "auto"
        np.testing.assert_allclose(outs[0], outs[1], atol=2e-3, rtol=2e-3)
        print("MULTIDEV_OK", loss)
""")


@pytest.mark.slow
def test_multidevice_train_step_runs():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", MULTIDEV_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert "MULTIDEV_OK" in out.stdout, out.stdout + out.stderr


def _run_subprocess(script, marker, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert marker in out.stdout, out.stdout + out.stderr


SHARDED_ROUTER_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    import jax.tree_util as jtu
    from repro.config import GSIConfig
    from repro.launch.mesh import carve_submeshes
    from repro.launch.serve import make_frontend, toy_triple
    from repro.models import build_model
    from repro.serving.gsi_engine import GSIServingEngine

    draft, target, prm = toy_triple()
    rng = jax.random.PRNGKey(0)
    ps = build_model(draft).init(jax.random.fold_in(rng, 1))
    pb = build_model(target).init(jax.random.fold_in(rng, 2))
    pp = build_model(prm).init(jax.random.fold_in(rng, 3))
    prompts = [[5, 6, 7, 8, 9, 3, 2, 11, 4, 4],
               [5, 6, 7, 8, 9, 3, 2, 11, 6], [2, 3, 4], [9, 8, 7, 6],
               [5, 6, 7, 8, 9, 3, 2, 11, 12], [1, 2]]

    def serve(meshes, temperature, sync):
        g = GSIConfig(n=2, max_step_tokens=6, max_steps=3,
                      temperature=temperature)
        engs = [GSIServingEngine(draft, target, prm, ps, pb, pp, g,
                                 paged=True, page_size=4, mesh=m)
                for m in meshes]
        sched = make_frontend(engs, capacity=2, sync=sync)
        ids = [sched.submit(np.asarray(p, np.int32)) for p in prompts]
        res = sched.run(jax.random.PRNGKey(42))
        return [np.asarray(res[i].tokens) for i in ids], engs

    subs = carve_submeshes(2, (1, 2))
    for sync, temp in ((True, 0.0), (True, 0.7), (False, 0.7)):
        base, _ = serve([None, None], temp, sync)
        shard, engs = serve(subs, temp, sync)
        for a, b in zip(base, shard):
            assert a.shape == b.shape and (a == b).all(), (sync, temp)
        print(f"identical sync={sync} temp={temp}")

    # sharding-spec assertions on the last sharded fleet
    eng = engs[0]
    tspecs = [str(l.sharding.spec)
              for l in jtu.tree_leaves(eng.params[1])]
    assert any("model" in s for s in tspecs), "target not sharded"
    rep = [str(l.sharding.spec)
           for l in jtu.tree_leaves((eng.params[0], eng.params[2]))]
    assert all("model" not in s for s in rep), "draft/PRM not replicated"
    state = eng.init_state(np.asarray([[3, 4, 5, 6]], np.int32))
    kv = [str(l.sharding.spec)
          for p, l in jtu.tree_flatten_with_path(state)[0]
          if "'B'" in str(p) and getattr(l, "ndim", 0) >= 4]
    assert any("model" in s for s in kv), "target KV pool not sharded"
    ids0 = {d.id for d in subs[0].devices.flat}
    ids1 = {d.id for d in subs[1].devices.flat}
    assert not ids0 & ids1, "submeshes overlap"
    print("SHARDED_ROUTER_OK")
""")


@pytest.mark.slow
def test_sharded_router_bitwise_identity():
    """2 replicas x (data=1, model=2) submeshes through the router are
    bit-identical to the unsharded 2-replica fleet — sync and async,
    greedy and temperature>0 — with target weights/KV verifiably on the
    ``model`` axis and draft/PRM replicated."""
    _run_subprocess(SHARDED_ROUTER_SCRIPT, "SHARDED_ROUTER_OK")


SHARDED_STACKS_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import numpy as np
    import jax
    from repro.config import GSIConfig
    from repro.launch.mesh import carve_submeshes
    from repro.launch.serve import make_frontend, toy_triple
    from repro.models import build_model
    from repro.serving.gsi_engine import GSIServingEngine

    draft, target, prm = toy_triple()
    rng = jax.random.PRNGKey(0)
    ps = build_model(draft).init(jax.random.fold_in(rng, 1))
    pp = build_model(prm).init(jax.random.fold_in(rng, 3))
    mesh = carve_submeshes(1, (1, 2))[0]
    prompts = [[3, 4, 5, 6, 7], [2, 3, 4], [9, 8, 7, 6, 5, 4]]

    for name, pat in (("local", ("local",)),
                      ("hybrid", ("full", "local"))):
        tgt = dataclasses.replace(target, layer_pattern=pat,
                                  window_size=8)
        pb = build_model(tgt).init(jax.random.fold_in(rng, 2))
        for temp in (0.0, 0.7):
            toks = []
            for m in (None, mesh):
                g = GSIConfig(n=2, max_step_tokens=6, max_steps=3,
                              temperature=temp)
                eng = GSIServingEngine(draft, tgt, prm, ps, pb, pp, g,
                                       paged=True, page_size=4, mesh=m)
                sched = make_frontend(eng, capacity=2, sync=True)
                ids = [sched.submit(np.asarray(p, np.int32))
                       for p in prompts]
                res = sched.run(jax.random.PRNGKey(9))
                toks.append([np.asarray(res[i].tokens) for i in ids])
            for a, b in zip(*toks):
                assert a.shape == b.shape and (a == b).all(), (name,
                                                               temp)
        print("stack", name, "ok")
    print("SHARDED_STACKS_OK")
""")


@pytest.mark.slow
def test_sharded_stacks_bitwise_identity():
    """Sliding-window (local) and hybrid full/local target stacks keep
    the sharded==unsharded token identity through the scheduler."""
    _run_subprocess(SHARDED_STACKS_SCRIPT, "SHARDED_STACKS_OK")


def test_mesh_single_device_engine_matches_unsharded(tiny_dense):
    """In-process tier-1 coverage: a (1,1) mesh engine routes decode
    through shard_map on the single real CPU device and stays
    bit-identical to the plain jit engine, with the sharded page pool's
    ledger conserved (bytes-weighted eviction armed via page_bytes)."""
    from repro.config import GSIConfig
    from repro.launch.mesh import carve_submeshes
    from repro.models import build_model
    from repro.serving import GSIScheduler, GSIServingEngine

    target = dataclasses.replace(tiny_dense, name="t1-tgt", num_layers=3)
    prm = dataclasses.replace(target, name="t1-prm", reward_head=True)
    params = (build_model(tiny_dense).init(jax.random.PRNGKey(0)),
              build_model(target).init(jax.random.PRNGKey(1)),
              build_model(prm).init(jax.random.PRNGKey(2)))
    g = GSIConfig(n=2, max_step_tokens=5, max_steps=3, temperature=0.7)
    mesh = carve_submeshes(1, (1, 1))[0]
    prompts = [[5, 6, 7, 8, 9], [2, 3, 4]]
    toks = []
    for m in (None, mesh):
        eng = GSIServingEngine(tiny_dense, target, prm, *params, g,
                               max_seq=64, paged=True, page_size=4,
                               mesh=m)
        sched = GSIScheduler(eng, capacity=2)
        ids = [sched.submit(np.asarray(p, np.int32)) for p in prompts]
        res = sched.run(jax.random.PRNGKey(5))
        toks.append([np.asarray(res[i].tokens) for i in ids])
    for a, b in zip(*toks):
        assert a.shape == b.shape and (a == b).all()
    assert eng.tp == 1 and eng.mesh is not None
    pool = eng.pager
    assert pool.page_bytes > 0  # bytes-weighted LRU armed in production
    assert pool.num_free + pool.num_referenced + pool.num_cached \
        == eng.num_pages
