"""Sharding rules + a real 8-device pjit/shard_map integration (subprocess).

The multi-device test runs in a subprocess because the 512-placeholder
device count must be set before jax initializes (conftest keeps the main
test process on the single real CPU device).
"""
import os
import subprocess
import sys
import textwrap

import jax
import pytest

from repro.models.common import ParamSpec

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


class FakeMesh:
    axis_names = ("data", "model")

    class devices:  # noqa: N801
        shape = (4, 2)

    shape = {"data": 4, "model": 2}


def _pspec(shape, axes, mode="train"):
    from repro.distributed.sharding import spec_pspec
    return spec_pspec(ParamSpec(shape, axes, "normal", 1.0), FakeMesh(),
                      mode)


def test_divisibility_fallback():
    # heads=3 not divisible by model=2 -> replicated
    assert _pspec((64, 3, 16), ("embed", "heads", "head"))[1] is None
    # heads=4 divisible -> sharded
    assert _pspec((64, 4, 16), ("embed", "heads", "head"))[1] == "model"
    # embed FSDP over data in train mode
    assert _pspec((64, 4, 16), ("embed", "heads", "head"))[0] == "data"
    # serve mode: embed replicated
    assert _pspec((64, 4, 16), ("embed", "heads", "head"),
                  "serve")[0] is None


def test_no_axis_reuse_within_one_param():
    # expert -> model and expert_mlp -> data must not collide with embed
    p = _pspec((8, 64, 32), ("expert", "embed", "expert_mlp"))
    used = [a for a in p if a]
    assert len(used) == len(set(used))


def test_batch_pspec():
    from repro.distributed.sharding import batch_pspec
    assert batch_pspec(_mesh_like((4, 2), ("data", "model")), 8) == "data"
    assert batch_pspec(_mesh_like((4, 2), ("data", "model")), 3) is None


def _mesh_like(shape, axes):
    class M:
        axis_names = axes

        class devices:  # noqa: N801
            pass
    M.devices.shape = shape
    return M()


MULTIDEV_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.config import get_config, reduced_config, TrainConfig
    from repro.distributed import context as dctx
    from repro.distributed.sharding import (as_shardings, param_pspecs,
                                            batch_pspec)
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.models import build_model
    from repro.optim import AdamW
    from repro.train import make_train_step

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    # MoE arch exercises the shard_map expert-parallel path for real
    cfg = reduced_config(get_config("qwen2-moe-a2.7b"))
    model = build_model(cfg)
    tcfg = TrainConfig(total_steps=5, warmup_steps=1)
    with dctx.use_mesh(mesh):
        p_sh = as_shardings(param_pspecs(model.param_specs(), mesh,
                                         "train"), mesh)
        params = jax.jit(model.init, out_shardings=p_sh)(
            jax.random.PRNGKey(0))
        opt = AdamW(tcfg)
        opt_state = opt.init(params)
        step = jax.jit(make_train_step(cfg, tcfg))
        B, S = 8, 16
        batch = {
            "tokens": jnp.asarray(
                np.random.randint(3, cfg.vocab_size, (B, S)), jnp.int32),
            "loss_mask": jnp.ones((B, S), jnp.float32),
        }
        sh = NamedSharding(mesh, P("data", None))
        batch = {k: jax.device_put(v, sh) for k, v in batch.items()}
        for i in range(3):
            params, opt_state, m = step(params, opt_state, batch)
        loss = float(m["loss"])
        assert np.isfinite(loss), loss
        # both expert-parallel modes agree (H2's repl vs gather dispatch)
        model = build_model(cfg)
        outs = []
        for mode in ("gather", "repl"):
            os.environ["REPRO_MOE_MODE"] = mode
            lg, _ = jax.jit(model.forward)(params, batch["tokens"][:, :8])
            outs.append(np.asarray(lg, np.float32))
        os.environ["REPRO_MOE_MODE"] = "auto"
        np.testing.assert_allclose(outs[0], outs[1], atol=2e-3, rtol=2e-3)
        print("MULTIDEV_OK", loss)
""")


@pytest.mark.slow
def test_multidevice_train_step_runs():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", MULTIDEV_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert "MULTIDEV_OK" in out.stdout, out.stdout + out.stderr
