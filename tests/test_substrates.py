"""Data / optimizer / checkpoint / latency-model / spec-decode tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.config import TrainConfig
from repro.core.spec_decode import speculative_verify
from repro.data import EOS, PAD, SEP, SyntheticReasoningTask
from repro.data.synthetic import D0, digits_to_tokens, tokens_to_int
from repro.optim import AdamW, clip_by_global_norm, cosine_schedule
from repro.serving.latency import HW_V5E, LatencyModel, ModelCost


# ---------------------------------------------------------------------------
# synthetic task
# ---------------------------------------------------------------------------

def test_digits_roundtrip():
    for x in [0, 7, 10, 123, 4096]:
        assert tokens_to_int(digits_to_tokens(x)) == x


def test_golden_reward_exact():
    task = SyntheticReasoningTask(seed=0)
    prob = task.sample_problem()
    steps = task.solution_steps(prob)
    flat = [t for s in steps for t in s]
    assert task.golden_reward(prob, flat) == 1.0
    assert task.is_correct(prob, flat)
    # corrupt the first step -> reward 0
    bad = list(flat)
    bad[0] = D0 + (bad[0] - D0 + 1) % 10
    assert task.golden_reward(prob, bad) == 0.0
    # correct prefix of k steps -> k / num_steps
    one = list(steps[0])
    assert task.golden_reward(prob, one) == pytest.approx(
        1.0 / prob.num_steps)


def test_lm_and_prm_batches_wellformed():
    task = SyntheticReasoningTask(seed=0)
    b = task.lm_batch(4, 48)
    assert b["tokens"].shape == (4, 48) and b["loss_mask"].shape == (4, 48)
    assert (b["loss_mask"] <= 1).all()
    pb = task.prm_batch(4, 48)
    assert set(pb) == {"tokens", "reward_labels", "reward_mask"}
    assert ((pb["reward_labels"] >= 0) & (pb["reward_labels"] <= 1)).all()
    # reward labels are monotone non-increasing per sequence? (errors only
    # break forward) — prefix reward never increases after breaking
    for row_lab, row_mask in zip(pb["reward_labels"], pb["reward_mask"]):
        vals = row_lab[row_mask > 0]
        diffs = np.diff(vals)
        # once broken, reward stays flat; otherwise grows by 1/num_steps
        assert (diffs > -1e-6).all() or (vals[-1] <= vals.max())


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_minimizes_quadratic():
    tcfg = TrainConfig(learning_rate=0.1, warmup_steps=1, total_steps=200,
                       weight_decay=0.0)
    opt = AdamW(tcfg)
    params = {"x": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(150):
        grads = {"x": 2 * params["x"]}
        params, state, _ = opt.update(grads, state, params)
    assert float(jnp.abs(params["x"]).max()) < 0.3


def test_clip_global_norm():
    g = {"a": jnp.full((3,), 100.0)}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert float(gn) == pytest.approx(np.sqrt(3 * 100.0 ** 2), rel=1e-5)
    norm = float(jnp.sqrt(jnp.sum(clipped["a"] ** 2)))
    assert norm == pytest.approx(1.0, rel=1e-4)


def test_cosine_schedule_shape():
    tcfg = TrainConfig(learning_rate=1.0, warmup_steps=10, total_steps=100)
    lr = cosine_schedule(tcfg)
    assert float(lr(0)) == 0.0
    assert float(lr(10)) == pytest.approx(1.0)
    assert float(lr(100)) == pytest.approx(0.0, abs=1e-6)
    assert float(lr(55)) < float(lr(20))


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path, tiny_dense):
    from repro.models import build_model
    m = build_model(tiny_dense)
    params = m.init(jax.random.PRNGKey(0))
    # include a bf16 leaf
    params["embed"]["embedding"] = params["embed"]["embedding"].astype(
        jnp.bfloat16)
    path = str(tmp_path / "ck.msgpack")
    save_checkpoint(path, params)
    restored = load_checkpoint(path, params)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


# ---------------------------------------------------------------------------
# latency model
# ---------------------------------------------------------------------------

def test_latency_model_orderings():
    lm = LatencyModel(ModelCost(1.5e9, 1024), ModelCost(7e9, 4096),
                      ModelCost(7e9, 4096), HW_V5E)
    kw = dict(n=4, step_len=20, ctx_len=512)
    t_s = lm.step_time(method="sbon_s", **kw)
    t_b = lm.step_time(method="sbon_b", **kw)
    t_gsi_hi = lm.step_time(method="gsi", accept_rate=0.95, **kw)
    t_gsi_lo = lm.step_time(method="gsi", accept_rate=0.2, **kw)
    t_rsd = lm.step_time(method="rsd", accept_rate=0.95, **kw)
    assert t_s < t_b                       # draft cheaper than target
    assert t_s < t_gsi_hi < t_gsi_lo       # rejections cost target decodes
    assert t_rsd < t_gsi_hi                # RSD skips the scoring pass
    assert t_gsi_hi < t_b                  # the paper's headline claim


# ---------------------------------------------------------------------------
# token-level speculative decoding exactness
# ---------------------------------------------------------------------------

def test_speculative_verify_statistics():
    V, k, B = 8, 1, 40_000
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    logits_S = jnp.broadcast_to(jax.random.normal(k1, (1, k, V)), (B, k, V))
    logits_B = jnp.broadcast_to(jax.random.normal(k2, (1, k, V)), (B, k, V))
    draft = jax.random.categorical(k3, logits_S[:, 0])[:, None]
    res = speculative_verify(jax.random.PRNGKey(4), draft, logits_S,
                             logits_B)
    # final token: draft if accepted else residual resample
    final = np.where(np.asarray(res.num_accepted) == 1,
                     np.asarray(draft[:, 0]), np.asarray(res.resample_tok))
    emp = np.bincount(final, minlength=V) / B
    target = np.asarray(jax.nn.softmax(logits_B[0, 0]))
    np.testing.assert_allclose(emp, target, atol=0.02)
