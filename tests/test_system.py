"""End-to-end behaviour tests: the paper's pipeline at miniature scale.

Trains a draft/target/PRM triple on the synthetic reasoning task, serves
with GSI and the baselines, and checks the qualitative claims the paper
makes (method ordering is checked statistically in benchmarks/; here we
assert the pipeline produces well-formed, graded outputs end to end).
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.config import GSIConfig, TrainConfig
from repro.data import SyntheticReasoningTask
from repro.launch.serve import evaluate, toy_triple, train_triple
from repro.serving import GSIServingEngine


@pytest.fixture(scope="module")
def trained_triple():
    task = SyntheticReasoningTask(seed=0, min_terms=2, max_terms=3,
                                  max_value=9)
    d, t, p = toy_triple()
    ps, pb, pp = train_triple(task, d, t, p, steps_draft=80,
                              steps_target=180, batch=24, seq=48)
    return task, (d, t, p), (ps, pb, pp)


def test_gsi_pipeline_end_to_end(trained_triple):
    task, cfgs, params = trained_triple
    g = GSIConfig(n=2, beta=8.0, threshold_u=0.4, max_step_tokens=8,
                  max_steps=4, min_step_reward=0.0)
    eng = GSIServingEngine(*cfgs, *params, g, max_seq=96)
    problems = [task.sample_problem() for _ in range(4)]
    res = evaluate(eng, task, problems, jax.random.PRNGKey(1))
    assert 0.0 <= res["accuracy"] <= 1.0
    assert 0.0 <= res["accept_rate"] <= 1.0
    assert res["stats"].draft_tokens > 0
    # tilted rewards were actually computed (log-ratio statistics exist)
    assert len(res["stats"].logp_ratio) > 0


def test_gsi_accept_rate_responds_to_threshold(trained_triple):
    task, cfgs, params = trained_triple
    problems = [task.sample_problem() for _ in range(4)]
    rates = []
    for u in (-10.0, 10.0):
        g = GSIConfig(n=2, beta=8.0, threshold_u=u, max_step_tokens=8,
                      max_steps=3, min_step_reward=0.0)
        eng = GSIServingEngine(*cfgs, *params, g, max_seq=96)
        res = evaluate(eng, task, problems, jax.random.PRNGKey(2))
        rates.append(res["accept_rate"])
    assert rates[0] == 1.0          # u = -inf accepts everything
    assert rates[1] == 0.0          # u = +inf rejects everything


def test_target_stronger_than_draft(trained_triple):
    """The trained target LM should fit the task better than the draft."""
    import jax.numpy as jnp
    from repro.models import build_model
    from repro.train.trainer import lm_loss
    task, (d, t, _), (ps, pb, _) = trained_triple
    batch = {k: jnp.asarray(v) for k, v in task.lm_batch(32, 48).items()}
    _, m_s = lm_loss(build_model(d), ps, batch)
    _, m_b = lm_loss(build_model(t), pb, batch)
    assert float(m_b["loss"]) < float(m_s["loss"])
