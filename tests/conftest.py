import dataclasses

import jax
import pytest

from repro.config import ModelConfig

# NOTE: never set xla_force_host_platform_device_count here — smoke tests
# and benchmarks must see the single real CPU device (dry-runs spawn their
# own process with 512 placeholder devices).


@pytest.fixture(scope="session")
def tiny_dense():
    return ModelConfig(
        name="t-dense", family="dense", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=64, head_dim=16,
        dtype="float32", param_dtype="float32")


@pytest.fixture(scope="session")
def tiny_triple(tiny_dense):
    draft = tiny_dense
    target = dataclasses.replace(draft, name="t-target", num_layers=3,
                                 d_model=96, head_dim=24)
    prm = dataclasses.replace(target, name="t-prm", reward_head=True)
    return draft, target, prm


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
