"""Model-level invariants: decode==forward, prefill==forward, score, rings."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config, reduced_config
from repro.models import build_model

CONSISTENCY_ARCHS = [
    "smollm-135m",        # dense GQA
    "gemma3-1b",          # local/global pattern + ring cache
    "qwen2-moe-a2.7b",    # MoE w/ shared experts
    "rwkv6-3b",           # attention-free recurrent state
    "recurrentgemma-9b",  # RG-LRU hybrid
    "seamless-m4t-medium",  # enc-dec cross attention
    "llama-3.2-vision-11b",  # interleaved cross attention
]


def _setup(arch, B=2, S=24):
    cfg = reduced_config(get_config(arch))
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 3,
                              cfg.vocab_size)
    src = None
    if cfg.encoder_layers:
        src = jax.random.normal(jax.random.PRNGKey(2),
                                (B, cfg.encoder_seq, cfg.d_model))
    elif cfg.cross_source_seq:
        src = jax.random.normal(jax.random.PRNGKey(2),
                                (B, cfg.cross_source_seq, cfg.d_model))
    return cfg, m, params, toks, src


@pytest.mark.parametrize("arch", CONSISTENCY_ARCHS)
def test_decode_matches_forward(arch):
    B, S, S0 = 2, 24, 8
    cfg, m, params, toks, src = _setup(arch, B, S)
    full, _ = m.forward(params, toks, source=src)
    lp, cache = m.prefill(params, toks[:, :S0], source=src, max_seq=S)
    np.testing.assert_allclose(lp, full[:, S0 - 1], atol=2e-4, rtol=2e-4)
    step = jax.jit(m.decode_step)
    for t in range(S0, S):
        lg, cache = step(params, cache, toks[:, t:t + 1],
                         jnp.full((B,), t, jnp.int32))
        np.testing.assert_allclose(lg, full[:, t], atol=5e-4, rtol=5e-4)


def test_ring_buffer_cache_matches_full_attention():
    """Sliding-window decode via ring buffer == full mask with window."""
    B, S, S0 = 1, 40, 16
    cfg = dataclasses.replace(reduced_config(get_config("gemma3-1b")),
                              window_size=16)  # < S so the ring wraps
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 3,
                              cfg.vocab_size)
    full, _ = m.forward(params, toks)   # window masking inside full attn
    _, cache = m.prefill(params, toks[:, :S0], max_seq=S)
    # local layers' cache is at most window-sized
    local_k = cache["blocks"]["p0"]["k"] if cache["blocks"] else \
        cache["rem"]["r0"]["k"]
    assert local_k.shape[-3] <= max(cfg.window_size, S0)
    step = jax.jit(m.decode_step)
    for t in range(S0, S):
        lg, cache = step(params, cache, toks[:, t:t + 1],
                         jnp.full((B,), t, jnp.int32))
        np.testing.assert_allclose(lg, full[:, t], atol=5e-4, rtol=5e-4)


def test_score_matches_forward_logprobs(tiny_dense):
    m = build_model(tiny_dense)
    params = m.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 3, 60)
    logits, _ = m.forward(params, toks[:, :-1])
    ref = jax.nn.log_softmax(logits, axis=-1)
    ref = jnp.take_along_axis(ref, toks[:, 1:, None], axis=-1)[..., 0]
    out = m.score(params, toks)
    np.testing.assert_allclose(out, ref, atol=1e-4, rtol=1e-4)


def test_live_mask_freezes_recurrent_state():
    cfg = reduced_config(get_config("rwkv6-3b"))
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B = 2
    cache = m.init_cache(B, 16)
    tok = jnp.array([[5], [6]], jnp.int32)
    pos = jnp.zeros((B,), jnp.int32)
    _, c1 = m.decode_step(params, cache, tok, pos,
                          live=jnp.array([True, False]))
    # frozen request's wkv state unchanged (zeros), live one updated
    wkv = (c1["blocks"]["p0"]["wkv"] if c1["blocks"] else
           c1["rem"]["r0"]["wkv"])
    assert float(jnp.abs(wkv[:, 1]).max()) == 0.0
    assert float(jnp.abs(wkv[:, 0]).max()) > 0.0


def test_reward_head_range(tiny_triple):
    _, _, prm_cfg = tiny_triple
    m = build_model(prm_cfg)
    params = m.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 10), 3, 60)
    r = m.reward(params, toks)
    assert r.shape == (2, 10)
    assert float(r.min()) >= 0.0 and float(r.max()) <= 1.0
