"""Deliverable (f): per-assigned-architecture smoke tests.

Each test instantiates a REDUCED variant of the same family (2 layers,
d_model<=512, <=4 experts), runs one forward pass and one train step on CPU,
and asserts output shapes + no NaNs.  The FULL configs are exercised by the
dry-run (launch/dryrun.py) via ShapeDtypeStructs only.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.config import TrainConfig, get_config, reduced_config
from repro.configs import ASSIGNED, PAPER_MODELS
from repro.models import build_model
from repro.models.common import padded_vocab
from repro.optim import AdamW
from repro.train import make_train_step


def _source_for(cfg, B, dtype=jnp.float32):
    if cfg.encoder_layers:
        return jnp.ones((B, cfg.encoder_seq, cfg.d_model), dtype)
    if cfg.cross_source_seq:
        return jnp.ones((B, cfg.cross_source_seq, cfg.d_model), dtype)
    return None


@pytest.mark.parametrize("arch", ASSIGNED)
def test_reduced_forward(arch):
    cfg = reduced_config(get_config(arch))
    assert cfg.num_layers == 2 and cfg.d_model <= 512
    if cfg.num_experts:
        assert cfg.num_experts <= 4
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 3,
                              cfg.vocab_size)
    logits, aux = model.forward(params, toks, source=_source_for(cfg, B))
    assert logits.shape == (B, S, padded_vocab(cfg))
    assert not jnp.isnan(logits).any()
    assert not jnp.isnan(jnp.asarray(aux)).any()


@pytest.mark.parametrize("arch", ASSIGNED)
def test_reduced_train_step(arch):
    cfg = reduced_config(get_config(arch))
    model = build_model(cfg)
    tcfg = TrainConfig(total_steps=10, warmup_steps=2)
    with_source = bool(cfg.encoder_layers or cfg.cross_source_seq)
    step = jax.jit(make_train_step(cfg, tcfg, with_source=with_source))
    params = model.init(jax.random.PRNGKey(0))
    opt = AdamW(tcfg)
    opt_state = opt.init(params)
    B, S = 2, 16
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 3,
                                     cfg.vocab_size),
        "loss_mask": jnp.ones((B, S), jnp.float32),
    }
    if with_source:
        batch["source"] = _source_for(cfg, B)
    params2, opt_state2, metrics = step(params, opt_state, batch)
    assert jnp.isfinite(metrics["loss"])
    assert jnp.isfinite(metrics["grad_norm"])
    # params actually changed
    l0 = jax.tree.leaves(params)[0]
    l1 = jax.tree.leaves(params2)[0]
    assert not jnp.allclose(l0, l1)


@pytest.mark.parametrize("arch", ASSIGNED + PAPER_MODELS)
def test_full_config_registered(arch):
    cfg = get_config(arch)
    assert cfg.param_count() > 0
    assert cfg.source  # every config cites its source
