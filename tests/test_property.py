"""Hypothesis property tests on the system's invariants."""
import pytest

pytest.importorskip("hypothesis")

import hypothesis.extra.numpy as hnp
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.core import theory, tilted_policy, tilted_rewards
from repro.sampling.sampler import top_p_filter
from repro.serving.pages import PagePool, RadixIndex, pages_for
from repro.serving.snapshot import index_records, restore_records

FINITE = dict(allow_nan=False, allow_infinity=False)


def probs(m):
    return hnp.arrays(np.float64, (m,),
                      elements=st.floats(0.01, 10.0, **FINITE)).map(
        lambda a: a / a.sum())


@settings(deadline=None, max_examples=30)
@given(p=probs(8), q=probs(8))
def test_divergences_nonnegative(p, q):
    assert float(theory.kl_divergence(jnp.asarray(p), jnp.asarray(q))) >= -1e-6
    assert float(theory.chi2_divergence(jnp.asarray(p),
                                        jnp.asarray(q))) >= -1e-6
    # KL(p||p) == 0
    assert float(theory.kl_divergence(jnp.asarray(p),
                                      jnp.asarray(p))) < 1e-6


@settings(deadline=None, max_examples=30)
@given(pi_b=probs(8), pi_s=probs(8),
       r=hnp.arrays(np.float64, (8,), elements=st.floats(0, 1, **FINITE)),
       beta=st.floats(0.1, 10.0))
def test_tilting_rewrite_identity(pi_b, pi_s, r, beta):
    """softmax(log pi_S + beta*r~) == tilted pi_B for ANY pi_S coverage."""
    r_t = tilted_rewards(jnp.asarray(r), jnp.log(jnp.asarray(pi_b)),
                         jnp.log(jnp.asarray(pi_s)), beta)
    lhs = jax.nn.softmax(jnp.log(jnp.asarray(pi_s)) + beta * r_t)
    rhs = tilted_policy(jnp.asarray(pi_b), jnp.asarray(r), beta)
    np.testing.assert_allclose(lhs, rhs, atol=1e-5)


@settings(deadline=None, max_examples=30)
@given(pi_b=probs(8),
       r=hnp.arrays(np.float64, (8,), elements=st.floats(0, 1, **FINITE)),
       beta=st.floats(0.1, 5.0))
def test_tilted_policy_increases_reward(pi_b, r, beta):
    """E_{tilted}[r] >= E_{pi_B}[r] (exponential tilting is monotone)."""
    t = tilted_policy(jnp.asarray(pi_b), jnp.asarray(r), beta)
    assert float(jnp.sum(t * r)) >= float(jnp.sum(jnp.asarray(pi_b) * r)) \
        - 1e-9


@settings(deadline=None, max_examples=25)
@given(logits=hnp.arrays(np.float32, (4, 16),
                         elements=st.floats(-5, 5, **FINITE)),
       top_p=st.floats(0.2, 0.99))
def test_top_p_keeps_argmax_and_mass(logits, top_p):
    out = top_p_filter(jnp.asarray(logits), top_p)
    kept = np.asarray(out) > -1e29
    # argmax always kept
    am = np.argmax(logits, -1)
    assert kept[np.arange(4), am].all()
    # kept mass >= top_p
    p = np.exp(logits - logits.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    assert ((p * kept).sum(-1) >= min(top_p, 1.0) - 1e-4).all()


@settings(deadline=None, max_examples=20)
@given(n=st.integers(1, 512), chi2=st.floats(0.0, 10.0),
       beta=st.floats(0.01, 2.0))
def test_theorem1_bound_monotone_decreasing_in_n(n, chi2, beta):
    b1 = float(theory.theorem1_kl_bound(n, chi2, beta, 1.0))
    b2 = float(theory.theorem1_kl_bound(n + 1, chi2, beta, 1.0))
    assert b2 <= b1 + 1e-9
    assert b1 >= -1e-6


# ---------------------------------------------------------------------------
# PagePool: refcount / radix-cache ledger invariants under random
# claim / ensure / publish / release / evicting-claim interleavings
# ---------------------------------------------------------------------------

PS = 4          # page size (tokens per page) for the pool machine


def _check_pool(pool: PagePool) -> None:
    """The allocator's global invariants (see serving/pages.py)."""
    free = set(pool.free)
    referenced = set(pool.refcount)
    cached = set(pool.cached)
    # page conservation: every page in exactly one state
    assert len(free) == len(pool.free), "free list holds duplicates"
    assert free | referenced | cached == set(range(pool.num_pages))
    assert not free & referenced and not free & cached
    assert not referenced & cached
    # refcounts strictly positive (never negative, never stale zero)
    assert all(rc >= 1 for rc in pool.refcount.values())
    # every assigned page is referenced; refcount >= number of readers
    readers = {}
    for pages in pool.assigned.values():
        assert len(set(pages)) == len(pages), "slot repeats a page"
        for p in pages:
            readers[p] = readers.get(p, 0) + 1
    assert set(readers) == referenced
    assert all(pool.refcount[p] == n for p, n in readers.items())
    # reservations are always honourable without eviction
    assert pool.num_free >= pool.num_claimed
    # cached pages are exactly the retained-but-unreferenced ones
    assert cached == pool.retained - referenced
    # the radix index never holds an unreachable (freed) page
    assert set(pool.index.nodes) == pool.retained
    # quantized pools: per-page scale slots live in lockstep with their
    # page — every out-of-circulation page carries exactly one scale
    # slot, no freed page leaves an orphaned scale behind
    if pool.quantized:
        assert pool.scale_slots == referenced | cached
    else:
        assert not pool.scale_slots


@settings(deadline=None, max_examples=40)
@given(data=st.data())
def test_page_pool_invariants_under_interleavings(data):
    num_pages = data.draw(st.integers(3, 12), label="num_pages")
    # quantized pools thread a per-page scale slot through the same
    # machine: the lockstep invariant in _check_pool must hold across
    # every interleaving, not just the happy path
    kv_dtype = data.draw(st.sampled_from([None, "int8", "fp8"]),
                         label="kv_dtype")
    # heterogeneous per-page byte costs (as under a sharded pool whose
    # cached pages mix quantized and fp footprints): the bytes-weighted
    # LRU only reorders the victim schedule — every ledger invariant
    # must hold regardless.  Zero costs exercise the `or 1` floor.
    page_bytes = data.draw(st.sampled_from([0, 64, 256]),
                           label="page_bytes")
    override = data.draw(
        st.dictionaries(st.integers(0, num_pages - 1),
                        st.integers(0, 500), max_size=num_pages),
        label="page_cost_override")
    pool = PagePool(num_pages, PS, index=RadixIndex(PS),
                    kv_dtype=kv_dtype, page_bytes=page_bytes,
                    page_cost_override=override)
    # small token alphabet so different "prompts" collide into shared
    # radix paths reasonably often
    next_slot = [0]
    slot_toks = {}                   # slot -> committed context tokens
    saved = [None]                   # last snapshot's records

    def live_slots():
        return sorted(pool.assigned)

    def op_claim():
        toks = data.draw(
            st.lists(st.integers(1, 3), min_size=PS,
                     max_size=PS * min(num_pages, 4)), label="prompt")
        shared, m = pool.match(toks[:(len(toks) - 1) // PS * PS])
        need = pages_for(len(toks) + 1, PS) - len(shared)
        if not pool.can_claim(need, shared):
            # admission would defer: nothing may have changed
            return
        slot = next_slot[0]
        next_slot[0] += 1
        pool.claim(slot, need, shared=shared)
        # prefill covers the prompt right away (engine.admit does this)
        pool.ensure(slot, pages_for(len(toks), PS))
        full = (len(toks) - 1) // PS
        if full:
            pool.publish(toks[:full * PS], pool.assigned[slot][:full])
        slot_toks[slot] = list(toks)

    def op_ensure():
        slots = live_slots()
        if not slots:
            return
        slot = data.draw(st.sampled_from(slots), label="ensure_slot")
        have = pool.blocks_assigned(slot)
        extra = data.draw(st.integers(0, pool.claimed.get(slot, 0)),
                          label="extra")
        pool.ensure(slot, have + extra)

    def op_release():
        slots = live_slots()
        if not slots:
            return
        slot = data.draw(st.sampled_from(slots), label="release_slot")
        pool.release(slot)
        slot_toks.pop(slot, None)

    def op_evict():
        want = data.draw(st.integers(1, num_pages), label="evict_n")
        pool.evict(want)

    def op_publish_decode_page():
        # decode-time publication: a live slot commits a few more tokens
        # and publishes every newly filled page it already owns (the
        # scheduler's _publish_decode path over the pool primitives)
        slots = [s for s in live_slots() if s in slot_toks]
        if not slots:
            return
        slot = data.draw(st.sampled_from(slots), label="pub_slot")
        grown = data.draw(st.lists(st.integers(1, 3), min_size=1,
                                   max_size=2 * PS), label="decoded")
        toks = slot_toks[slot] + grown
        slot_toks[slot] = toks
        # only pages the slot actually holds are publishable (claims for
        # not-yet-ensured pages stay reservations)
        full = min((len(toks) - 1) // PS, pool.blocks_assigned(slot))
        if full:
            pool.publish(toks[:full * PS], pool.assigned[slot][:full])

    def op_snapshot():
        saved[0] = index_records(pool)

    def op_restore():
        # restore the last snapshot into the live pool: dedupes against
        # surviving subtrees, draws fresh pages for evicted ones, never
        # touches referenced pages or reserved free pages
        if saved[0] is None:
            return
        remap = restore_records(pool, saved[0])
        assert not (set(remap.values()) & set(pool.refcount))

    ops = {"claim": op_claim, "ensure": op_ensure,
           "release": op_release, "evict": op_evict,
           "publish_decode_page": op_publish_decode_page,
           "snapshot": op_snapshot, "restore": op_restore}
    for _ in range(data.draw(st.integers(1, 30), label="steps")):
        ops[data.draw(st.sampled_from(sorted(ops)), label="op")]()
        _check_pool(pool)
    # drain: releasing everything leaves only free + cached pages
    for slot in live_slots():
        pool.release(slot)
        _check_pool(pool)
    assert pool.num_free + pool.num_cached == pool.num_pages
    # a snapshot of the drained pool restores into a *fresh* pool (the
    # migration/warm-restart shape) with the ledger intact and every
    # record admitted (the fresh pool has pages for all of them)
    records = index_records(pool)
    fresh = PagePool(num_pages, PS, index=RadixIndex(PS),
                     kv_dtype=kv_dtype, page_bytes=page_bytes)
    remap = restore_records(fresh, records)
    _check_pool(fresh)
    assert len(remap) == len(records)
    assert fresh.num_cached == len(records)
    # and a full eviction returns the pool to pristine
    pool.evict(num_pages)
    _check_pool(pool)
    assert pool.num_free == pool.num_pages


@settings(deadline=None, max_examples=25)
@given(data=st.data())
def test_per_replica_page_conservation_under_routed_admission(data):
    """Router scale-out invariant: replicas share no pages, so routed
    admission — each request's claim/ensure/publish landing on the pool
    the (real) affinity function picks — must preserve every replica's
    ledger invariants independently, under arbitrary interleaving with
    releases and evictions on other replicas."""
    from repro.serving.router import preamble_hash

    n_replicas = data.draw(st.integers(2, 3), label="replicas")
    kv_dtype = data.draw(st.sampled_from([None, "int8"]),
                         label="kv_dtype")
    pools = [PagePool(data.draw(st.integers(3, 10), label=f"pages{i}"),
                      PS, index=RadixIndex(PS), kv_dtype=kv_dtype)
             for i in range(n_replicas)]
    next_slot = [0]

    def route(toks):
        """The router's placement tiers over bare pools: longest cached
        radix match first, then the first-chunk hash."""
        best, best_len = None, 0
        for i, pool in enumerate(pools):
            _, m = pool.match(toks[:(len(toks) - 1) // PS * PS])
            if m > best_len:
                best, best_len = i, m
        if best is not None:
            return best
        return preamble_hash(toks[:PS], n_replicas)

    def live_slots(pool):
        return sorted(pool.assigned)

    def op_routed_claim():
        toks = data.draw(
            st.lists(st.integers(1, 3), min_size=PS, max_size=PS * 4),
            label="prompt")
        pool = pools[route(toks)]
        shared, _ = pool.match(toks[:(len(toks) - 1) // PS * PS])
        need = pages_for(len(toks) + 1, PS) - len(shared)
        if not pool.can_claim(need, shared):
            return                   # this replica defers; others untouched
        slot = next_slot[0]
        next_slot[0] += 1
        pool.claim(slot, need, shared=shared)
        pool.ensure(slot, pages_for(len(toks), PS))
        full = (len(toks) - 1) // PS
        if full:
            pool.publish(toks[:full * PS], pool.assigned[slot][:full])

    def op_release():
        candidates = [(i, s) for i, p in enumerate(pools)
                      for s in live_slots(p)]
        if not candidates:
            return
        i, slot = data.draw(st.sampled_from(candidates), label="release")
        pools[i].release(slot)

    def op_evict():
        i = data.draw(st.integers(0, n_replicas - 1), label="evict_pool")
        pools[i].evict(data.draw(st.integers(1, pools[i].num_pages),
                                 label="evict_n"))

    ops = {"claim": op_routed_claim, "release": op_release,
           "evict": op_evict}
    for _ in range(data.draw(st.integers(1, 25), label="steps")):
        ops[data.draw(st.sampled_from(sorted(ops)), label="op")]()
        for pool in pools:
            _check_pool(pool)
    # drain the whole fleet: every replica back to free + cached only
    for pool in pools:
        for slot in live_slots(pool):
            pool.release(slot)
            _check_pool(pool)
        assert pool.num_free + pool.num_cached == pool.num_pages


# ----------------------------------------------------------------------
# Async pipelined scheduler: page conservation under submit/step/harvest
# ----------------------------------------------------------------------

_ASYNC_ENGINE = {}


def _async_sched():
    """One tiny paged engine + pipelined scheduler, reset per example.

    Built lazily and cached at module level so every hypothesis example
    reuses the compiled jitted phases (fresh_state rebuilds the page
    pool, radix index and scheduler bookkeeping between examples).
    """
    if "sched" not in _ASYNC_ENGINE:
        import dataclasses

        from repro.config import GSIConfig, ModelConfig
        from repro.models import build_model
        from repro.serving import GSIScheduler, GSIServingEngine

        draft = ModelConfig(
            name="prop-async-d", family="dense", num_layers=1, d_model=32,
            num_heads=2, num_kv_heads=2, d_ff=64, vocab_size=32,
            head_dim=16, dtype="float32", param_dtype="float32")
        target = dataclasses.replace(draft, name="prop-async-t")
        prm = dataclasses.replace(draft, name="prop-async-p",
                                  reward_head=True)
        params = tuple(build_model(c).init(jax.random.PRNGKey(i))
                       for i, c in enumerate((draft, target, prm)))
        g = GSIConfig(n=2, max_step_tokens=4, max_steps=2, beta=4.0,
                      min_step_reward=-1.0)
        eng = GSIServingEngine(draft, target, prm, *params, g,
                               max_seq=64, paged=True, page_size=8,
                               num_pages=12)
        _ASYNC_ENGINE["sched"] = GSIScheduler(eng, capacity=2, sync=False,
                                              prompt_pad_len=24)
    sched = _ASYNC_ENGINE["sched"]
    sched.fresh_state()
    return sched


@settings(deadline=None, max_examples=8)
@given(data=st.data())
def test_async_pipeline_page_conservation_under_interleaving(data):
    """Interleaving submit / step / flush / preempt on the pipelined
    scheduler preserves the page ledger conservation law after every
    operation, never reacquires a slot bound by an in-flight ticket
    (the scheduler raises if it would), never drops a paused request,
    and drains to a complete response set."""
    sched = _async_sched()
    pool = sched.engine.pager
    rng = [jax.random.PRNGKey(data.draw(st.integers(0, 2**31 - 1),
                                        label="seed"))]
    submitted = [0]
    preempted = [0]

    def check():
        assert pool.num_free + pool.num_referenced + pool.num_cached \
            == pool.num_pages
        assert pool.num_in_use <= pool.num_pages

    def op_submit():
        pre = data.draw(st.sampled_from([0, 1]), label="preamble")
        tail = data.draw(st.lists(st.integers(3, 9), min_size=1,
                                  max_size=4), label="tail")
        prompt = [5 + pre] * 9 + tail       # one shared full page + tail
        sched.submit(np.asarray(prompt, np.int32),
                     request_id=f"p{submitted[0]}",
                     max_steps=data.draw(st.integers(1, 2), label="budget"))
        submitted[0] += 1

    def op_step():
        rng[0], k = jax.random.split(rng[0])
        sched.step(k)

    def op_flush():
        sched.flush()

    def op_preempt():
        # blind pause of a random submitted id: preempt() returns False
        # for ids that are unknown / queued / finished — the ledger must
        # conserve either way, and a paused request may never be dropped
        if not submitted[0]:
            return
        which = data.draw(st.integers(0, submitted[0] - 1),
                          label="preempt_id")
        if sched.preempt(f"p{which}"):
            preempted[0] += 1

    ops = {"submit": op_submit, "step": op_step, "flush": op_flush,
           "preempt": op_preempt}
    for _ in range(data.draw(st.integers(1, 12), label="steps")):
        ops[data.draw(st.sampled_from(sorted(ops)), label="op")]()
        check()
    # bounded drain: every submitted request must complete (each pause
    # costs at most one extra admission step)
    for _ in range(8 * submitted[0] + 2 * preempted[0] + 4):
        if not (sched.queue or sched.pool.num_live or sched.has_pending):
            break
        op_step()
        check()
    assert len(sched.responses) == submitted[0]
    assert sched.pool.num_free == sched.capacity
    assert sched.stats.preemptions == preempted[0]
    assert sched.stats.resumes == preempted[0]
