"""Hypothesis property tests on the system's invariants."""
import pytest

pytest.importorskip("hypothesis")

import hypothesis.extra.numpy as hnp
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.core import theory, tilted_policy, tilted_rewards
from repro.sampling.sampler import top_p_filter

FINITE = dict(allow_nan=False, allow_infinity=False)


def probs(m):
    return hnp.arrays(np.float64, (m,),
                      elements=st.floats(0.01, 10.0, **FINITE)).map(
        lambda a: a / a.sum())


@settings(deadline=None, max_examples=30)
@given(p=probs(8), q=probs(8))
def test_divergences_nonnegative(p, q):
    assert float(theory.kl_divergence(jnp.asarray(p), jnp.asarray(q))) >= -1e-6
    assert float(theory.chi2_divergence(jnp.asarray(p),
                                        jnp.asarray(q))) >= -1e-6
    # KL(p||p) == 0
    assert float(theory.kl_divergence(jnp.asarray(p),
                                      jnp.asarray(p))) < 1e-6


@settings(deadline=None, max_examples=30)
@given(pi_b=probs(8), pi_s=probs(8),
       r=hnp.arrays(np.float64, (8,), elements=st.floats(0, 1, **FINITE)),
       beta=st.floats(0.1, 10.0))
def test_tilting_rewrite_identity(pi_b, pi_s, r, beta):
    """softmax(log pi_S + beta*r~) == tilted pi_B for ANY pi_S coverage."""
    r_t = tilted_rewards(jnp.asarray(r), jnp.log(jnp.asarray(pi_b)),
                         jnp.log(jnp.asarray(pi_s)), beta)
    lhs = jax.nn.softmax(jnp.log(jnp.asarray(pi_s)) + beta * r_t)
    rhs = tilted_policy(jnp.asarray(pi_b), jnp.asarray(r), beta)
    np.testing.assert_allclose(lhs, rhs, atol=1e-5)


@settings(deadline=None, max_examples=30)
@given(pi_b=probs(8),
       r=hnp.arrays(np.float64, (8,), elements=st.floats(0, 1, **FINITE)),
       beta=st.floats(0.1, 5.0))
def test_tilted_policy_increases_reward(pi_b, r, beta):
    """E_{tilted}[r] >= E_{pi_B}[r] (exponential tilting is monotone)."""
    t = tilted_policy(jnp.asarray(pi_b), jnp.asarray(r), beta)
    assert float(jnp.sum(t * r)) >= float(jnp.sum(jnp.asarray(pi_b) * r)) \
        - 1e-9


@settings(deadline=None, max_examples=25)
@given(logits=hnp.arrays(np.float32, (4, 16),
                         elements=st.floats(-5, 5, **FINITE)),
       top_p=st.floats(0.2, 0.99))
def test_top_p_keeps_argmax_and_mass(logits, top_p):
    out = top_p_filter(jnp.asarray(logits), top_p)
    kept = np.asarray(out) > -1e29
    # argmax always kept
    am = np.argmax(logits, -1)
    assert kept[np.arange(4), am].all()
    # kept mass >= top_p
    p = np.exp(logits - logits.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    assert ((p * kept).sum(-1) >= min(top_p, 1.0) - 1e-4).all()


@settings(deadline=None, max_examples=20)
@given(n=st.integers(1, 512), chi2=st.floats(0.0, 10.0),
       beta=st.floats(0.01, 2.0))
def test_theorem1_bound_monotone_decreasing_in_n(n, chi2, beta):
    b1 = float(theory.theorem1_kl_bound(n, chi2, beta, 1.0))
    b2 = float(theory.theorem1_kl_bound(n + 1, chi2, beta, 1.0))
    assert b2 <= b1 + 1e-9
    assert b1 >= -1e-6
