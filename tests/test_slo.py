"""SLO-aware serving: chunked prefill, preemption, deadlines, streaming.

Layers of coverage:
  * Chunked-prefill identity: admitting a long prompt over several
    engine steps (per-step prefill token budget) commits exactly the
    same greedy tokens as one-shot admission, across dense and
    paged+prefix engines, sync and async pipelines, and across
    full / sliding-window / hybrid-recurrent stacks — while the
    per-step commit bound (``prefill_commit_max``) provably shrinks.
  * Preempt/resume round trip: a paused request resumes through a
    radix splice and finishes with tokens identical to an un-preempted
    greedy run; the page ledger conserves through the pause.
  * Priority admission: a deferring higher-priority request preempts
    the lowest-priority live slot (pause, never drop).
  * Deadline accounting: ``deadline_s`` is pure accounting — misses
    are counted, nothing is cancelled.
  * Streaming: per-request callbacks observe materialize order, carry
    monotone step indices, reassemble to the response tokens exactly,
    and end with a final event carrying the finish reason.
  * Duplicate request ids are rejected for the scheduler's lifetime
    (regression: ids were silently reusable once the first copy
    finished, corrupting the responses map).
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.config import GSIConfig, ModelConfig
from repro.models import build_model
from repro.serving import GSIScheduler, GSIServingEngine, TokenStream

PAD = 0

PRE_A = np.asarray([5 + (i % 24) for i in range(17)], np.int32)
PRE_B = np.asarray([30 + (i % 20) for i in range(17)], np.int32)


def _prompt(pre, tail):
    return np.concatenate([pre, np.asarray(tail, np.int32)])


def _triple(draft):
    target = dataclasses.replace(draft, name=draft.name + "-t",
                                 num_layers=3)
    prm = dataclasses.replace(target, name=draft.name + "-p",
                              reward_head=True)
    params = (build_model(draft).init(jax.random.PRNGKey(0)),
              build_model(target).init(jax.random.PRNGKey(1)),
              build_model(prm).init(jax.random.PRNGKey(2)))
    return (draft, target, prm), params


@pytest.fixture(scope="module")
def triple(tiny_triple):
    draft, target, prm = tiny_triple
    params = (build_model(draft).init(jax.random.PRNGKey(0)),
              build_model(target).init(jax.random.PRNGKey(1)),
              build_model(prm).init(jax.random.PRNGKey(2)))
    return (draft, target, prm), params


@pytest.fixture(scope="module")
def greedy():
    # temperature 0: per-row trajectories depend only on the committed
    # context, so any scheduling of the same prompts must reproduce the
    # same tokens bit-for-bit
    return GSIConfig(n=2, max_step_tokens=5, max_steps=3, beta=4.0,
                     min_step_reward=-1.0, temperature=0.0)


@pytest.fixture(scope="module")
def nostop(greedy):
    # no EOS / reward early-exit: preemption tests need the victim to
    # keep decoding until its step budget, not finish under the test
    return dataclasses.replace(greedy, eos_token_id=-1,
                               min_step_reward=-1e9)


def _engine(triple, g, **kw):
    cfgs, params = triple
    return GSIServingEngine(*cfgs, *params, g, max_seq=96, **kw)


def _serve(engine, prompts, budgets, *, sync=True, capacity=2, seed=42,
           chunk_tokens=0, cache_aware=False):
    sched = GSIScheduler(engine, capacity=capacity, sync=sync,
                         cache_aware=cache_aware, chunk_tokens=chunk_tokens)
    ids = [sched.submit(p, request_id=f"r{i}", max_steps=budgets[i])
           for i, p in enumerate(prompts)]
    out = sched.run(jax.random.PRNGKey(seed))
    tokens = {r: out[r].tokens.tolist() for r in ids}
    return tokens, sched


# ----------------------------------------------------------------------
# Chunked prefill == one-shot prefill (greedy identity)
# ----------------------------------------------------------------------

@pytest.mark.parametrize("sync", [True, False])
@pytest.mark.parametrize("chunk", [8, 16])
def test_chunked_prefill_identity_paged(triple, greedy, sync, chunk):
    """Chunked admission commits the same greedy tokens as one-shot,
    and bounds the per-jitted-call prompt commit by the chunk budget."""
    prompts = [_prompt(PRE_A, [33 + i, 34, 4]) for i in range(3)] + \
              [_prompt(PRE_B, [43, 44, 4])]
    budgets = [1, 2, 2, 1]
    plain, sched_p = _serve(
        _engine(triple, greedy, paged=True, page_size=8), prompts,
        budgets, sync=sync, cache_aware=True)
    chunked, sched_c = _serve(
        _engine(triple, greedy, paged=True, page_size=8), prompts,
        budgets, sync=sync, cache_aware=True, chunk_tokens=chunk)
    assert chunked == plain
    # the decode-stall proxy: the most prompt tokens committed by ONE
    # jitted call obeys the budget, while one-shot admission commits at
    # least a whole prompt (and sums co-admitted prompts) in one call
    assert 0 < sched_c.stats.prefill_commit_max <= chunk
    assert sched_p.stats.prefill_commit_max >= max(p.size for p in prompts)
    assert sched_c.stats.prefill_commit_max \
        < sched_p.stats.prefill_commit_max


def test_chunked_prefill_identity_dense(triple, greedy):
    """Chunking is independent of the paged cache: dense engines chunk
    through the same extend path."""
    prompts = [_prompt(PRE_A, [33 + i, 34, 4]) for i in range(3)]
    budgets = [1, 2, 1]
    plain, _ = _serve(_engine(triple, greedy), prompts, budgets)
    chunked, sched = _serve(_engine(triple, greedy), prompts, budgets,
                            chunk_tokens=8)
    assert chunked == plain
    assert sched.stats.prefill_commit_max <= 8


@pytest.mark.parametrize("pattern,window", [
    (("full",), 0),
    (("full", "local"), 12),
    (("recurrent", "full"), 0),
])
def test_chunked_identity_across_stacks(greedy, pattern, window):
    """full / sliding-window / hybrid-recurrent stacks: chunked prefill
    is layout-agnostic (the recurrent state and local windows must
    advance identically whether the prompt arrives in one or many
    jitted calls)."""
    base = ModelConfig(
        name=f"t-slo-{'-'.join(pattern)}-{window}", family="dense"
        if "recurrent" not in pattern else "hybrid",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
        vocab_size=64, head_dim=16, dtype="float32", param_dtype="float32",
        layer_pattern=pattern, window_size=window or 4096)
    triple = _triple(base)
    prompts = [_prompt(PRE_A, [33 + i, 34, 4]) for i in range(3)]
    budgets = [1, 2, 1]
    plain, _ = _serve(_engine(triple, greedy, paged=True, page_size=8),
                      prompts, budgets)
    chunked, _ = _serve(_engine(triple, greedy, paged=True, page_size=8),
                        prompts, budgets, chunk_tokens=8)
    assert chunked == plain


# ----------------------------------------------------------------------
# Preempt / resume
# ----------------------------------------------------------------------

def test_preempt_resume_round_trip(triple, nostop):
    """Pause -> publish committed pages -> resume via radix splice:
    tokens identical to the never-preempted run, pages conserved, and
    the resume admission hits the prefix cache."""
    victim = _prompt(PRE_A, [33, 34, 4])
    # baseline: the same request, never preempted
    base, _ = _serve(_engine(triple, nostop, paged=True, page_size=8),
                     [victim], [3], capacity=1, cache_aware=True)

    eng = _engine(triple, nostop, paged=True, page_size=8)
    sched = GSIScheduler(eng, capacity=1, cache_aware=True)
    rid = sched.submit(victim, request_id="v", max_steps=3)
    rng = jax.random.PRNGKey(42)
    rng, k = jax.random.split(rng)
    sched.step(k)                         # one decode step, then pause
    hits_before = sched.stats.prefix_hits
    assert sched.preempt(rid)
    assert sched.pool.slot_of(rid) is None     # slot released by the pause
    pool = eng.pager
    assert pool.num_free + pool.num_referenced + pool.num_cached \
        == pool.num_pages
    # paused, not dropped: the request is queued again and resumes
    assert sched.queue and sched.queue[0].id == rid
    out = sched.run(rng)
    assert out[rid].tokens.tolist() == base["r0"]
    assert out[rid].preemptions == 1
    assert sched.stats.preemptions == 1
    assert sched.stats.resumes == 1
    # the splice: resume re-admission matched the published pages
    assert sched.stats.prefix_hits > hits_before
    assert pool.num_free + pool.num_referenced + pool.num_cached \
        == pool.num_pages


def test_preempt_not_preemptible_states(triple, nostop):
    """preempt() returns False for unknown / queued / finished ids."""
    eng = _engine(triple, nostop, paged=True, page_size=8)
    sched = GSIScheduler(eng, capacity=1, cache_aware=True)
    assert not sched.preempt("nope")
    a = sched.submit(_prompt(PRE_A, [33, 34, 4]), max_steps=1)
    b = sched.submit(_prompt(PRE_B, [43, 44, 4]), max_steps=1)
    assert not sched.preempt(b)           # still queued (capacity 1)
    out = sched.run(jax.random.PRNGKey(0))
    assert set(out) == {a, b}
    assert not sched.preempt(a)           # finished
    assert sched.stats.preemptions == 0


@pytest.mark.parametrize("sync", [True, False])
def test_priority_preemption_pauses_lowest(triple, nostop, sync):
    """A deferring higher-priority request pauses the lowest-priority
    live slot; both finish with their un-contended greedy tokens."""
    low = _prompt(PRE_A, [33, 34, 4])
    high = _prompt(PRE_B, [43, 44, 4])
    base_low, _ = _serve(_engine(triple, nostop, paged=True, page_size=8),
                         [low], [3], capacity=2, cache_aware=True)
    base_high, _ = _serve(_engine(triple, nostop, paged=True, page_size=8),
                          [high], [2], capacity=2, cache_aware=True)

    eng = _engine(triple, nostop, paged=True, page_size=8)
    sched = GSIScheduler(eng, capacity=1, sync=sync, cache_aware=True)
    lo = sched.submit(low, request_id="lo", max_steps=3)
    rng = jax.random.PRNGKey(42)
    rng, k = jax.random.split(rng)
    sched.step(k)                         # low occupies the only slot
    hi = sched.submit(high, request_id="hi", max_steps=2, priority=1)
    out = sched.run(rng)
    assert out[lo].tokens.tolist() == base_low["r0"]
    assert out[hi].tokens.tolist() == base_high["r0"]
    assert sched.stats.preemptions >= 1
    assert sched.stats.resumes >= 1
    assert out[lo].preemptions >= 1
    assert out[hi].preemptions == 0
    pool = eng.pager
    assert pool.num_free + pool.num_referenced + pool.num_cached \
        == pool.num_pages


def test_priority_orders_admission(triple, greedy):
    """Within the queue, the highest arrived priority class admits
    first (FIFO inside a class)."""
    eng = _engine(triple, greedy)
    sched = GSIScheduler(eng, capacity=1)
    sched.submit([5, 6, 4], request_id="p0", max_steps=1)
    sched.submit([7, 3, 4], request_id="p2", max_steps=1, priority=2)
    sched.submit([9, 8, 4], request_id="p1", max_steps=1, priority=1)
    out = sched.run(jax.random.PRNGKey(0))
    order = sorted(out.values(), key=lambda r: r.admitted_at)
    assert [r.request_id for r in order] == ["p2", "p1", "p0"]


# ----------------------------------------------------------------------
# Deadlines
# ----------------------------------------------------------------------

def test_deadline_miss_accounting(triple, greedy):
    """deadline_s is accounting only: a missed deadline is counted and
    flagged on the response, the request still finishes normally."""
    eng = _engine(triple, greedy)
    sched = GSIScheduler(eng, capacity=2)
    miss = sched.submit([5, 6, 4], request_id="miss", max_steps=2,
                        deadline_s=0.0)
    make = sched.submit([7, 3, 4], request_id="make", max_steps=1,
                        deadline_s=3600.0)
    none = sched.submit([9, 8, 4], request_id="none", max_steps=1)
    out = sched.run(jax.random.PRNGKey(0))
    assert out[miss].finish_reason            # finished despite the miss
    assert out[miss].deadline_missed
    assert not out[make].deadline_missed
    assert not out[none].deadline_missed      # no deadline, never a miss
    assert sched.stats.deadline_misses == 1


# ----------------------------------------------------------------------
# Streaming
# ----------------------------------------------------------------------

@pytest.mark.parametrize("sync", [True, False])
def test_stream_reassembles_response(triple, greedy, sync):
    """Per-request streams reassemble to the response tokens exactly,
    with monotone step indices and a trailing final event."""
    eng = _engine(triple, greedy, paged=True, page_size=8)
    sched = GSIScheduler(eng, capacity=2, sync=sync, cache_aware=True)
    streams = {}
    for i in range(3):
        streams[f"r{i}"] = TokenStream()
        sched.submit(_prompt(PRE_A, [33 + i, 34, 4]), request_id=f"r{i}",
                     max_steps=2, stream=streams[f"r{i}"])
    out = sched.run(jax.random.PRNGKey(7))
    for rid, stream in streams.items():
        events = list(stream)
        assert events, rid
        assert events[-1].final
        assert events[-1].finish_reason == out[rid].finish_reason
        assert all(not e.final for e in events[:-1])
        steps = [e.step for e in events[:-1]]
        assert steps == sorted(steps)
        got = np.concatenate([np.asarray(e.tokens, np.int32)
                              for e in events]
                             + [np.zeros((0,), np.int32)])
        assert got.tolist() == out[rid].tokens.tolist()
        # timing surfaced through the stream: first event at/after TTFT
        assert events[0].t >= out[rid].arrival_time


def test_stream_order_matches_materialize_order_async(triple, greedy):
    """Under the async pipeline, a request's stream events fire in
    materialize order — callback timestamps never run backwards."""
    eng = _engine(triple, greedy, paged=True, page_size=8)
    sched = GSIScheduler(eng, capacity=2, sync=False, cache_aware=True)
    seen = []

    def tap(event):
        seen.append((event.request_id, event.step, event.final, event.t))

    for i in range(4):
        sched.submit(_prompt(PRE_A, [33 + i, 34, 4]), request_id=f"r{i}",
                     max_steps=2, stream=tap)
    sched.run(jax.random.PRNGKey(7))
    assert seen
    times = [t for *_x, t in seen]
    assert times == sorted(times)
    # per request: steps monotone, exactly one final event, fired last
    for rid in {s[0] for s in seen}:
        mine = [s for s in seen if s[0] == rid]
        assert [s[2] for s in mine].count(True) == 1
        assert mine[-1][2], rid
        steps = [s[1] for s in mine[:-1]]
        assert steps == sorted(steps)


# ----------------------------------------------------------------------
# Duplicate request ids (regression)
# ----------------------------------------------------------------------

def test_duplicate_request_id_rejected(triple, greedy):
    """submit() rejects a reused id — queued, live, or already
    finished (the silent-overwrite regression)."""
    eng = _engine(triple, greedy)
    sched = GSIScheduler(eng, capacity=1)
    sched.submit([5, 6, 4], request_id="dup", max_steps=1)
    with pytest.raises(ValueError, match="duplicate request id"):
        sched.submit([7, 3, 4], request_id="dup", max_steps=1)
    out = sched.run(jax.random.PRNGKey(0))
    assert set(out) == {"dup"}
    # the regression: after the first copy FINISHED, a reused id used to
    # be accepted silently and clobbered the responses map
    with pytest.raises(ValueError, match="duplicate request id"):
        sched.submit([9, 8, 4], request_id="dup", max_steps=1)
    assert set(sched.responses) == {"dup"}
